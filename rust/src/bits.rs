//! Minimal bit-vector utilities shared by the CNN (weight rows, activation
//! maps) and the CAM (tags, compare-enable masks).
//!
//! Bits are packed little-endian into `u64` words: bit `i` lives in word
//! `i / 64` at position `i % 64`.  The hot loops of the native decode path
//! ([`crate::cnn`]) operate directly on the word slices, so the layout here
//! *is* the performance contract.


/// A fixed-length packed bit vector.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zeros vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-ones vector of `len` bits (trailing bits in the last word clear).
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec { words: vec![!0u64; len.div_ceil(64)], len };
        v.mask_tail();
        v
    }

    /// Build from explicit bools.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Build from the low `len` bits of a u128 (little-endian).
    pub fn from_u128(value: u128, len: usize) -> Self {
        assert!(len <= 128);
        let mut v = BitVec::zeros(len);
        if len > 0 {
            v.words[0] = value as u64;
            if len > 64 {
                v.words[1] = (value >> 64) as u64;
            }
            v.mask_tail();
        }
        v
    }

    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Assert (in debug builds) that no bit past `len` is set in the last
    /// word.  The word-level decode kernels trust this invariant — a slack
    /// bit would inflate `count_ones`, corrupt `hamming`, and surface as a
    /// phantom match — so every mutation path calls this before returning.
    #[inline]
    pub fn ensure_tail_clear(&self) {
        debug_assert!(self.tail_is_clear(), "tail slack bits set in BitVec of len {}", self.len);
    }

    fn tail_is_clear(&self) -> bool {
        let rem = self.len % 64;
        rem == 0
            || self.words.last().map_or(true, |&last| last & !((1u64 << rem) - 1) == 0)
    }

    /// Resize to `new_len` bits in place, reusing the allocation.
    ///
    /// Growth zero-extends.  Shrinking truncates **and clears** every bit
    /// past `new_len` — both whole stale high words and the slack of the new
    /// last word — so a later grow (or a word-level kernel that scans the
    /// full slice) never observes stale data.
    pub fn resize(&mut self, new_len: usize) {
        let new_words = new_len.div_ceil(64);
        self.words.resize(new_words, 0);
        self.len = new_len;
        self.mask_tail();
        self.ensure_tail_clear();
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if `len() == 0`.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    ///
    /// Panics if `i >= len()`, in release builds too: indices in
    /// `len..words*64` land inside the word slice, so a `debug_assert!`
    /// alone would let them silently slip through in release.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "bit index {i} out of bounds for BitVec of len {}", self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Write bit `i`.
    ///
    /// Panics if `i >= len()` (see [`Self::get`]): a stray write into the
    /// tail slack of the last word would corrupt `count_ones`/`iter_ones`
    /// without any index ever failing the word-slice bounds check.
    #[inline]
    pub fn set(&mut self, i: usize, value: bool) {
        assert!(i < self.len, "bit index {i} out of bounds for BitVec of len {}", self.len);
        let (w, b) = (i / 64, i % 64);
        if value {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Number of set bits.
    #[inline]
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if no bit is set.
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// In-place AND with another vector of the same length.
    ///
    /// Panics on a length mismatch in release builds too: `zip` would
    /// silently stop at the shorter slice, leaving high words of `self`
    /// un-ANDed — and if `other` were longer with a dirty tail, OR (below)
    /// could smuggle slack bits in.  The kernels trust tails are clear.
    #[inline]
    pub fn and_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len, "and_assign length mismatch");
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= *b;
        }
        self.ensure_tail_clear();
    }

    /// In-place OR with another vector of the same length.
    ///
    /// Panics on a length mismatch in release builds too (see
    /// [`Self::and_assign`]).
    #[inline]
    pub fn or_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len, "or_assign length mismatch");
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
        self.ensure_tail_clear();
    }

    /// Hamming distance to another vector of the same length.
    pub fn hamming(&self, other: &BitVec) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).map(|(a, b)| (a ^ b).count_ones() as usize).sum()
    }

    /// Indices of all set bits, ascending.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Serialize to bytes: the packed words in ascending order, each as 8
    /// little-endian bytes — `ceil(len/64) * 8` bytes total, independent of
    /// host endianness.  The inverse is [`Self::from_bytes`]; the snapshot
    /// and WAL encodings ([`crate::store`]) depend on this layout being
    /// exact and stable.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for &w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize from the [`Self::to_bytes`] layout, validating strictly:
    /// the byte count must be exactly `ceil(len/64) * 8`, and any set bit in
    /// the tail slack past `len` is rejected rather than masked — slack
    /// garbage in a stored image means the producer (or the medium) is
    /// corrupt, and masking it would let a damaged file decode "cleanly".
    pub fn from_bytes(bytes: &[u8], len: usize) -> Result<Self, FromBytesError> {
        let expected = len.div_ceil(64) * 8;
        if bytes.len() != expected {
            return Err(FromBytesError::LengthMismatch { expected, got: bytes.len() });
        }
        let mut v = BitVec::zeros(len);
        for (w, chunk) in v.words.iter_mut().zip(bytes.chunks_exact(8)) {
            *w = u64::from_le_bytes(chunk.try_into().expect("chunks_exact yields 8 bytes"));
        }
        let rem = len % 64;
        if rem != 0 {
            if let Some(&last) = v.words.last() {
                if last & !((1u64 << rem) - 1) != 0 {
                    return Err(FromBytesError::TailBitsSet { len });
                }
            }
        }
        v.ensure_tail_clear();
        Ok(v)
    }

    /// Raw word access (hot-path decode loops).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable raw word access.
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }
}

/// Why [`BitVec::from_bytes`] refused the input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FromBytesError {
    /// The byte slice is not exactly `ceil(len/64) * 8` bytes.
    LengthMismatch { expected: usize, got: usize },
    /// A bit past `len` is set in the last word (tail-slack garbage).
    TailBitsSet { len: usize },
}

impl std::fmt::Display for FromBytesError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FromBytesError::LengthMismatch { expected, got } => {
                write!(f, "expected {expected} bytes, got {got}")
            }
            FromBytesError::TailBitsSet { len } => {
                write!(f, "set bits past the {len}-bit length")
            }
        }
    }
}

impl std::error::Error for FromBytesError {}

/// Word-level kernels shared by the decode (AND-reduce) and candidate
/// compare (XOR-popcount) hot paths.
///
/// The scalar forms are written over plain `u64` slices so the compiler can
/// autovectorize them; building with `--features simd` (nightly, enables
/// `portable_simd`) swaps in explicit 4-lane `std::simd` bodies.  Both
/// variants are bit-identical by construction — the lanes carry the same
/// words — and the property battery in `tests/decode_kernel.rs` checks the
/// composed results against a per-bit reference.
pub mod kernel {
    #[cfg(feature = "simd")]
    use std::simd::u64x4;

    /// `dst[i] &= src[i]` over equal-length slices (the winner-take-all
    /// AND-reduce step).
    #[inline]
    pub fn and_words(dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "and_words length mismatch");
        #[cfg(feature = "simd")]
        {
            let mut d = dst.chunks_exact_mut(4);
            let mut s = src.chunks_exact(4);
            for (dc, sc) in (&mut d).zip(&mut s) {
                (u64x4::from_slice(dc) & u64x4::from_slice(sc)).copy_to_slice(dc);
            }
            for (a, b) in d.into_remainder().iter_mut().zip(s.remainder()) {
                *a &= *b;
            }
        }
        #[cfg(not(feature = "simd"))]
        for (a, b) in dst.iter_mut().zip(src) {
            *a &= *b;
        }
    }

    /// Hamming distance between equal-length word slices: popcount of the
    /// XOR (the candidate tag compare).  Exact only when both sides keep
    /// their tail slack clear — which `BitVec`/`BitSlab` guarantee.
    #[inline]
    pub fn xor_popcount(a: &[u64], b: &[u64]) -> usize {
        assert_eq!(a.len(), b.len(), "xor_popcount length mismatch");
        #[cfg(feature = "simd")]
        {
            let mut total = 0usize;
            let mut ca = a.chunks_exact(4);
            let mut cb = b.chunks_exact(4);
            for (xa, xb) in (&mut ca).zip(&mut cb) {
                let x = u64x4::from_slice(xa) ^ u64x4::from_slice(xb);
                total += x.to_array().iter().map(|w| w.count_ones() as usize).sum::<usize>();
            }
            for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
                total += (x ^ y).count_ones() as usize;
            }
            total
        }
        #[cfg(not(feature = "simd"))]
        a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones() as usize).sum()
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn kernels_match_scalar_reference_across_lengths() {
            // cover the simd remainder path: lengths 0..9 words
            let mut seed = 0x9E37_79B9_7F4A_7C15u64;
            let mut next = || {
                seed ^= seed << 13;
                seed ^= seed >> 7;
                seed ^= seed << 17;
                seed
            };
            for words in 0..9usize {
                let a: Vec<u64> = (0..words).map(|_| next()).collect();
                let b: Vec<u64> = (0..words).map(|_| next()).collect();
                let mut dst = a.clone();
                and_words(&mut dst, &b);
                let want: Vec<u64> = a.iter().zip(&b).map(|(x, y)| x & y).collect();
                assert_eq!(dst, want, "words={words}");
                let pop = xor_popcount(&a, &b);
                let want: usize =
                    a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones() as usize).sum();
                assert_eq!(pop, want, "words={words}");
            }
        }
    }
}

/// A dense matrix of equal-length bit rows packed into one contiguous
/// `Vec<u64>` — the storage behind the CNN weight matrix and the CAM tag
/// column.
///
/// Row `r` occupies words `r * stride .. r * stride + stride` where
/// `stride == ceil(row_bits / 64)`, each row laid out exactly like a
/// [`BitVec`] of `row_bits` bits (little-endian words, tail slack clear).
/// Keeping all rows in one allocation makes a row-major sweep — the
/// winner-take-all AND-reduce, the candidate tag compare — a linear walk
/// over memory instead of a pointer chase through `Vec<BitVec>`, which is
/// the point of the slab kernels.
///
/// The per-row tail invariant is identical to `BitVec`'s: bits past
/// `row_bits` in a row's last word are always zero, so word-level popcounts
/// over whole rows are exact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitSlab {
    words: Vec<u64>,
    rows: usize,
    row_bits: usize,
    stride: usize,
}

impl BitSlab {
    /// All-zeros slab of `rows` rows of `row_bits` bits each.
    pub fn zeros(rows: usize, row_bits: usize) -> Self {
        let stride = row_bits.div_ceil(64);
        BitSlab { words: vec![0; rows * stride], rows, row_bits, stride }
    }

    /// Build from materialized rows, validating that every row has
    /// `row_bits` bits.  Intended for restore paths, not hot loops.
    pub fn from_rows(rows: &[BitVec], row_bits: usize) -> Self {
        let mut slab = BitSlab::zeros(rows.len(), row_bits);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), row_bits, "slab row {r} length mismatch");
            row.ensure_tail_clear();
            slab.row_words_mut(r).copy_from_slice(row.words());
        }
        slab
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Bits per row.
    #[inline]
    pub fn row_bits(&self) -> usize {
        self.row_bits
    }

    /// Words per row (`ceil(row_bits / 64)`).
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// The packed words of row `r`.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.words[r * self.stride..(r + 1) * self.stride]
    }

    /// Mutable packed words of row `r`.  Callers must uphold the per-row
    /// tail invariant; [`Self::debug_assert_row_tail_clear`] checks it.
    #[inline]
    pub fn row_words_mut(&mut self, r: usize) -> &mut [u64] {
        &mut self.words[r * self.stride..(r + 1) * self.stride]
    }

    /// Read bit `bit` of row `r`.
    #[inline]
    pub fn get(&self, r: usize, bit: usize) -> bool {
        assert!(bit < self.row_bits, "bit {bit} out of bounds for {}-bit rows", self.row_bits);
        (self.row_words(r)[bit / 64] >> (bit % 64)) & 1 == 1
    }

    /// Write bit `bit` of row `r`.
    #[inline]
    pub fn set(&mut self, r: usize, bit: usize, value: bool) {
        assert!(bit < self.row_bits, "bit {bit} out of bounds for {}-bit rows", self.row_bits);
        let stride = self.stride;
        let w = &mut self.words[r * stride + bit / 64];
        if value {
            *w |= 1 << (bit % 64);
        } else {
            *w &= !(1 << (bit % 64));
        }
    }

    /// Clear every bit of row `r`.
    pub fn clear_row(&mut self, r: usize) {
        self.row_words_mut(r).fill(0);
    }

    /// Clear every bit of every row.
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Materialize row `r` as an owned [`BitVec`] (restore/snapshot paths,
    /// not hot loops).
    pub fn row(&self, r: usize) -> BitVec {
        let mut v = BitVec::zeros(self.row_bits);
        v.words_mut().copy_from_slice(self.row_words(r));
        v.ensure_tail_clear();
        v
    }

    /// Materialize every row (snapshot encoding, PJRT weight upload).
    pub fn to_rows(&self) -> Vec<BitVec> {
        (0..self.rows).map(|r| self.row(r)).collect()
    }

    /// Debug-assert row `r` has no slack bits set past `row_bits`.
    #[inline]
    pub fn debug_assert_row_tail_clear(&self, r: usize) {
        debug_assert!(
            {
                let rem = self.row_bits % 64;
                rem == 0
                    || self
                        .row_words(r)
                        .last()
                        .map_or(true, |&last| last & !((1u64 << rem) - 1) == 0)
            },
            "tail slack bits set in slab row {r} ({}-bit rows)",
            self.row_bits
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!v.get(i));
            v.set(i, true);
            assert!(v.get(i));
        }
        assert_eq!(v.count_ones(), 8);
        v.set(64, false);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 7);
    }

    #[test]
    fn ones_masks_tail() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.words()[1], (1u64 << 6) - 1);
    }

    #[test]
    fn and_or_semantics() {
        let a = BitVec::from_bools(&[true, true, false, false]);
        let b = BitVec::from_bools(&[true, false, true, false]);
        let mut and = a.clone();
        and.and_assign(&b);
        assert_eq!(and, BitVec::from_bools(&[true, false, false, false]));
        let mut or = a.clone();
        or.or_assign(&b);
        assert_eq!(or, BitVec::from_bools(&[true, true, true, false]));
    }

    #[test]
    fn hamming_distance() {
        let a = BitVec::from_u128(0b1011, 100);
        let b = BitVec::from_u128(0b0110, 100);
        assert_eq!(a.hamming(&b), 3);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn iter_ones_crosses_word_boundaries() {
        let mut v = BitVec::zeros(200);
        let idx = [3, 63, 64, 100, 199];
        for &i in &idx {
            v.set(i, true);
        }
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), idx);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn set_in_tail_slack_panics_in_release_too() {
        // len=70 → the word slice holds 128 bits; indices 70..127 must still
        // panic or they would corrupt count_ones/iter_ones undetected.
        let mut v = BitVec::zeros(70);
        v.set(100, true);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn get_in_tail_slack_panics_in_release_too() {
        let v = BitVec::zeros(70);
        v.get(100);
    }

    #[test]
    fn tail_invariant_preserved_under_legal_ops() {
        // count_ones over the tail slack stays exact after heavy set/unset.
        let mut v = BitVec::zeros(70);
        for i in 0..70 {
            v.set(i, true);
        }
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.iter_ones().count(), 70);
        for i in (0..70).step_by(2) {
            v.set(i, false);
        }
        assert_eq!(v.count_ones(), 35);
    }

    #[test]
    fn byte_roundtrip_at_word_boundaries() {
        // the lengths the snapshot codec cares about: empty, single-bit,
        // one-under/at/over a word boundary, and two full words
        for len in [0usize, 1, 63, 64, 65, 127, 128] {
            let mut v = BitVec::zeros(len);
            for i in (0..len).step_by(7) {
                v.set(i, true);
            }
            if len > 0 {
                v.set(len - 1, true); // exercise the highest legal bit
            }
            let bytes = v.to_bytes();
            assert_eq!(bytes.len(), len.div_ceil(64) * 8, "len={len}");
            assert_eq!(BitVec::from_bytes(&bytes, len).unwrap(), v, "len={len}");
        }
    }

    #[test]
    fn from_bytes_rejects_wrong_byte_count() {
        for len in [0usize, 1, 63, 64, 65, 127, 128] {
            let good = BitVec::zeros(len).to_bytes();
            let mut long = good.clone();
            long.push(0);
            if len > 0 {
                let mut short = good.clone();
                short.pop();
                assert!(
                    matches!(
                        BitVec::from_bytes(&short, len),
                        Err(FromBytesError::LengthMismatch { .. })
                    ),
                    "len={len} short"
                );
            }
            assert!(
                matches!(
                    BitVec::from_bytes(&long, len),
                    Err(FromBytesError::LengthMismatch { .. })
                ),
                "len={len} long"
            );
        }
    }

    #[test]
    fn from_bytes_rejects_tail_slack_garbage() {
        // for every non-word-multiple length, a set bit just past `len`
        // must be rejected, not silently masked
        for len in [1usize, 63, 65, 127] {
            let mut bytes = BitVec::zeros(len).to_bytes();
            let slack_bit = len % 64; // first illegal bit within the last word
            let last_word_byte = (len / 64) * 8 + slack_bit / 8;
            bytes[last_word_byte] |= 1 << (slack_bit % 8);
            assert!(
                matches!(BitVec::from_bytes(&bytes, len), Err(FromBytesError::TailBitsSet { .. })),
                "len={len}"
            );
        }
        // word-multiple lengths have no slack: every bit pattern is legal
        for len in [64usize, 128] {
            let bytes = vec![0xFFu8; len / 8];
            assert_eq!(BitVec::from_bytes(&bytes, len).unwrap().count_ones(), len);
        }
    }

    #[test]
    fn word_ops_hold_tail_invariant_at_boundary_lengths() {
        // 63 (slack within one word), 64 (no slack), 65 (one slack-heavy
        // second word): the lengths where tail bookkeeping goes wrong first.
        for len in [63usize, 64, 65] {
            let a = BitVec::ones(len);
            let mut b = BitVec::zeros(len);
            for i in (0..len).step_by(3) {
                b.set(i, true);
            }

            let mut and = a.clone();
            and.and_assign(&b);
            and.ensure_tail_clear();
            assert_eq!(and, b, "len={len}");

            let mut or = b.clone();
            or.or_assign(&a);
            or.ensure_tail_clear();
            assert_eq!(or, a, "len={len}");
            assert_eq!(or.count_ones(), len, "len={len}");

            let bytes = a.to_bytes();
            let back = BitVec::from_bytes(&bytes, len).unwrap();
            back.ensure_tail_clear();
            assert_eq!(back.count_ones(), len, "len={len}");
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn and_assign_length_mismatch_panics_in_release_too() {
        let mut a = BitVec::zeros(64);
        a.and_assign(&BitVec::zeros(65));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn or_assign_length_mismatch_panics_in_release_too() {
        let mut a = BitVec::ones(65);
        a.or_assign(&BitVec::ones(64));
    }

    #[test]
    fn resize_shrink_truncates_and_zeroes() {
        // grow-then-shrink must not leave stale high words or slack bits
        let mut v = BitVec::ones(200);
        v.resize(65);
        assert_eq!(v.len(), 65);
        assert_eq!(v.words().len(), 2);
        assert_eq!(v.count_ones(), 65);
        v.resize(63);
        assert_eq!(v.words().len(), 1);
        assert_eq!(v.count_ones(), 63);
        // re-grow: the reclaimed region must read as zeros
        v.resize(200);
        assert_eq!(v.count_ones(), 63);
        assert!(!v.get(63));
        assert!(!v.get(199));
    }

    #[test]
    fn resize_boundary_lengths_roundtrip_bytes() {
        for len in [63usize, 64, 65] {
            let mut v = BitVec::ones(128);
            v.resize(len);
            assert_eq!(v.count_ones(), len, "len={len}");
            let bytes = v.to_bytes();
            assert_eq!(BitVec::from_bytes(&bytes, len).unwrap(), v, "len={len}");
        }
    }

    #[test]
    fn slab_rows_match_bitvec_layout() {
        for row_bits in [1usize, 63, 64, 65, 130] {
            let rows: Vec<BitVec> = (0..5)
                .map(|r| {
                    let mut v = BitVec::zeros(row_bits);
                    for i in (r..row_bits).step_by(5) {
                        v.set(i, true);
                    }
                    v
                })
                .collect();
            let slab = BitSlab::from_rows(&rows, row_bits);
            assert_eq!(slab.rows(), 5);
            assert_eq!(slab.stride(), row_bits.div_ceil(64));
            for (r, row) in rows.iter().enumerate() {
                assert_eq!(slab.row_words(r), row.words(), "row_bits={row_bits} r={r}");
                assert_eq!(&slab.row(r), row, "row_bits={row_bits} r={r}");
                slab.debug_assert_row_tail_clear(r);
            }
            assert_eq!(slab.to_rows(), rows, "row_bits={row_bits}");
        }
    }

    #[test]
    fn slab_set_get_clear() {
        let mut slab = BitSlab::zeros(3, 70);
        slab.set(1, 69, true);
        slab.set(1, 0, true);
        slab.set(2, 64, true);
        assert!(slab.get(1, 69));
        assert!(slab.get(2, 64));
        assert!(!slab.get(0, 69));
        assert_eq!(slab.row(1).count_ones(), 2);
        slab.clear_row(1);
        assert!(slab.row(1).is_zero());
        assert!(slab.get(2, 64)); // neighbors untouched
        slab.clear();
        assert!(slab.row(2).is_zero());
    }

    #[test]
    fn from_u128_layout() {
        let v = BitVec::from_u128(u128::MAX, 128);
        assert_eq!(v.count_ones(), 128);
        let v = BitVec::from_u128(1u128 << 64, 65);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![64]);
    }
}
