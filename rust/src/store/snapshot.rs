//! The snapshot codec: one bank's full engine image in a versioned,
//! checksummed binary file.
//!
//! File layout (all little-endian, built on [`crate::util::codec`]):
//!
//! ```text
//! [magic "CSSS"][version u16][reserved u16 = 0]
//! [payload_len u64][checksum u64]                    -- FNV-1a of payload
//! [payload]
//! ```
//!
//! The payload serializes everything [`LookupEngine`] needs to come back
//! *bit-identical*: the design geometry, the tag-bit selection, the CNN
//! weight rows (including stale superposed weights — recomputing them from
//! the live tags would change λ and energy), the CAM rows + valid bits,
//! the stale-delete counter, the retrain threshold and the insert cursor.
//! Decoding is total: every malformed input — wrong magic, unknown
//! version, length or checksum mismatch, geometry that fails
//! [`DesignConfig::validate`], bit vectors with tail garbage — surfaces as
//! a typed [`StoreError`], never a panic (the codec fuzz battery flips
//! every byte of a valid file and asserts exactly this).
//!
//! Compatibility rule: readers accept the exact version set
//! [`SNAPSHOT_ACCEPTED_VERSIONS`] and refuse anything else with
//! [`StoreError::Incompatible`].  Version 2 appended the bloom pre-filter
//! section (cell counters + key count); version-1 images decode with no
//! filter section and the restore path rebuilds the filter from the valid
//! tags — deterministic, so the rebuilt filter equals the one a v2 image
//! of the same bank would carry.  Any further layout change must bump
//! [`SNAPSHOT_VERSION`] again.
//!
//! Writes are atomic: the image goes to `<path>.tmp`, is synced, then
//! renamed over the old snapshot — a crash mid-write leaves the previous
//! snapshot intact.

use std::path::Path;

use crate::bits::BitVec;
use crate::cam::{CamArray, MatchlineKind};
use crate::cnn::{ClusteredNetwork, Selection};
use crate::config::DesignConfig;
use crate::coordinator::engine::LookupEngine;
use crate::store::StoreError;
use crate::util::codec::{put_bitvec, put_f64, put_u32, put_u64, Cursor};
use crate::util::hash::fnv1a_bytes;

/// Snapshot file magic.
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"CSSS";

/// On-disk snapshot format version written by this build.
pub const SNAPSHOT_VERSION: u16 = 2;

/// Versions this build decodes (see the module docs for the v1→v2 delta).
pub const SNAPSHOT_ACCEPTED_VERSIONS: [u16; 2] = [1, 2];

/// Sanity bound on the filter cell count read from disk: the largest legal
/// table for M = [`MAX_GEOM`] entries at 8 cells/entry, rounded to the next
/// power of two.
const MAX_FILTER_CELLS: u64 = 1 << 24;

/// Bytes before the payload.
pub const SNAPSHOT_HEADER_LEN: usize = 24;

/// Sanity bound on every geometry scalar read from disk — far past any
/// design point, tight enough that corrupt lengths cannot drive giant
/// loops or allocations.
const MAX_GEOM: u64 = 1 << 20;

/// A decoded (or to-be-encoded) bank image.
#[derive(Debug, Clone, PartialEq)]
pub struct BankImage {
    pub cfg: DesignConfig,
    /// Tag-bit selection: positions (cluster-major) and bits per cluster.
    pub positions: Vec<u32>,
    pub k: u32,
    /// CNN weight rows, `c·l` rows of `m` bits (stale weights included).
    pub rows: Vec<BitVec>,
    /// CAM rows, `m` tags of `n` bits (invalid slots keep residual bits).
    pub tags: Vec<BitVec>,
    /// Valid bits, `m` of them.
    pub valid: BitVec,
    /// The bank's bloom pre-filter (v2+ images).  `None` — decoded from a
    /// v1 image — makes [`Self::into_engine`] rebuild it from the valid
    /// tags; the encoder writes an absent filter as a zero cell count.
    pub filter: Option<crate::cam::BankFilter>,
    pub stale_deletes: u64,
    pub retrain_threshold: f64,
    pub insert_cursor: u64,
    /// The WAL generation this image subsumes: on recovery, a log with an
    /// *older* generation is discarded (its records are already in here —
    /// a crash interrupted the compaction between snapshot and log reset).
    /// Stamped by [`crate::store::BankStore::compact`]; 0 for an image
    /// that has never been through a compaction cycle.
    pub wal_generation: u64,
}

impl BankImage {
    /// Capture a live engine.
    pub fn from_engine(e: &LookupEngine) -> BankImage {
        BankImage {
            cfg: e.config().clone(),
            positions: e.selection().positions().iter().map(|&p| p as u32).collect(),
            k: e.selection().k() as u32,
            rows: e.network().weight_rows(),
            tags: e.cam().tag_rows(),
            valid: e.cam().valid_bits().clone(),
            filter: Some(e.search_state().filter().clone()),
            stale_deletes: e.stale_delete_count() as u64,
            retrain_threshold: e.retrain_threshold,
            insert_cursor: e.insert_cursor() as u64,
            wal_generation: 0,
        }
    }

    /// Rebuild the engine.  Every structural invariant is re-validated
    /// (the image may have been decoded from disk).
    pub fn into_engine(self) -> Result<LookupEngine, StoreError> {
        let k = self.k as usize;
        if k == 0 || self.positions.len() % k != 0 {
            return Err(StoreError::Corrupt(format!(
                "selection of {} positions does not fill whole {k}-bit clusters",
                self.positions.len()
            )));
        }
        let positions: Vec<usize> = self.positions.iter().map(|&p| p as usize).collect();
        let selection = Selection::explicit(positions, k);
        let net = ClusteredNetwork::from_rows(
            self.cfg.c,
            self.cfg.l,
            self.cfg.m,
            self.cfg.zeta,
            self.rows,
        )
        .map_err(StoreError::Corrupt)?;
        let cam = CamArray::from_parts(self.cfg.n, self.cfg.zeta, self.tags, self.valid)
            .map_err(StoreError::Corrupt)?;
        LookupEngine::from_parts(
            self.cfg,
            selection,
            net,
            cam,
            self.filter,
            self.stale_deletes as usize,
            self.retrain_threshold,
            self.insert_cursor as usize,
        )
        .map_err(StoreError::Corrupt)
    }

    /// Serialize to complete file bytes (header + checksummed payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        put_u64(&mut p, self.cfg.m as u64);
        put_u64(&mut p, self.cfg.n as u64);
        put_u64(&mut p, self.cfg.zeta as u64);
        put_u64(&mut p, self.cfg.c as u64);
        put_u64(&mut p, self.cfg.l as u64);
        put_u64(&mut p, self.cfg.shards as u64);
        p.push(match self.cfg.ml_kind {
            MatchlineKind::Nor => 0,
            MatchlineKind::Nand => 1,
        });
        put_u32(&mut p, self.cfg.node.len() as u32);
        p.extend_from_slice(self.cfg.node.as_bytes());
        put_u32(&mut p, self.k);
        put_u32(&mut p, self.positions.len() as u32);
        for &pos in &self.positions {
            put_u32(&mut p, pos);
        }
        put_f64(&mut p, self.retrain_threshold);
        put_u64(&mut p, self.stale_deletes);
        put_u64(&mut p, self.insert_cursor);
        put_u64(&mut p, self.wal_generation);
        put_bitvec(&mut p, &self.valid);
        for t in &self.tags {
            put_bitvec(&mut p, t);
        }
        for r in &self.rows {
            put_bitvec(&mut p, r);
        }
        // v2 filter section: cell count (0 = no filter carried), cells, keys.
        match &self.filter {
            Some(f) => {
                put_u64(&mut p, f.cells().len() as u64);
                for &cell in f.cells() {
                    put_u32(&mut p, cell);
                }
                put_u64(&mut p, f.keys());
            }
            None => put_u64(&mut p, 0),
        }

        let mut out = Vec::with_capacity(SNAPSHOT_HEADER_LEN + p.len());
        out.extend_from_slice(&SNAPSHOT_MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a_bytes(&p).to_le_bytes());
        out.extend_from_slice(&p);
        out
    }

    /// Decode complete file bytes.  Total — see the module docs.
    pub fn decode(data: &[u8]) -> Result<BankImage, StoreError> {
        if data.len() < SNAPSHOT_HEADER_LEN {
            return Err(StoreError::Corrupt(format!(
                "snapshot of {} bytes is shorter than its {SNAPSHOT_HEADER_LEN}-byte header",
                data.len()
            )));
        }
        if data[..4] != SNAPSHOT_MAGIC {
            return Err(StoreError::Corrupt("bad magic in snapshot header".into()));
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if !SNAPSHOT_ACCEPTED_VERSIONS.contains(&version) {
            return Err(StoreError::Incompatible(format!(
                "snapshot format version {version}, this build reads {SNAPSHOT_ACCEPTED_VERSIONS:?}"
            )));
        }
        if data[6] != 0 || data[7] != 0 {
            return Err(StoreError::Corrupt("nonzero reserved bytes in snapshot header".into()));
        }
        // lint:allow(infallible: 8-byte slice by construction, header length
        // was checked before entering this branch)
        let payload_len = u64::from_le_bytes(<[u8; 8]>::try_from(&data[8..16]).expect("8 bytes"));
        let payload = &data[SNAPSHOT_HEADER_LEN..];
        if payload_len != payload.len() as u64 {
            return Err(StoreError::Corrupt(format!(
                "snapshot payload length {payload_len} != {} bytes present",
                payload.len()
            )));
        }
        // lint:allow(infallible: 8-byte slice by construction, see the header
        // length check above)
        let want = u64::from_le_bytes(<[u8; 8]>::try_from(&data[16..24]).expect("8 bytes"));
        let got = fnv1a_bytes(payload);
        if want != got {
            return Err(StoreError::Corrupt(format!(
                "snapshot checksum mismatch: header {want:#018x}, computed {got:#018x}"
            )));
        }

        let mut c = Cursor::new(payload);
        let geom = |what: &str, v: u64| -> Result<usize, StoreError> {
            if v == 0 || v > MAX_GEOM {
                return Err(StoreError::Corrupt(format!("{what} = {v} out of range")));
            }
            Ok(v as usize)
        };
        let m = geom("M", c.take_u64()?)?;
        let n = geom("N", c.take_u64()?)?;
        let zeta = geom("ζ", c.take_u64()?)?;
        let cl_c = geom("c", c.take_u64()?)?;
        let l = geom("l", c.take_u64()?)?;
        let shards = geom("shards", c.take_u64()?)?;
        let ml_kind = match c.take_u8()? {
            0 => MatchlineKind::Nor,
            1 => MatchlineKind::Nand,
            other => {
                return Err(StoreError::Corrupt(format!("unknown match-line kind {other}")))
            }
        };
        let node_len = c.take_u32()? as usize;
        if node_len > c.remaining() {
            return Err(StoreError::Corrupt(format!(
                "node name of {node_len} bytes exceeds the remaining payload"
            )));
        }
        let node = String::from_utf8(c.take(node_len)?.to_vec())
            .map_err(|_| StoreError::Corrupt("node name is not UTF-8".into()))?;
        let cfg = DesignConfig { m, n, zeta, c: cl_c, l, ml_kind, node, shards };
        cfg.validate().map_err(|e| StoreError::Corrupt(format!("invalid geometry: {e}")))?;

        let k = c.take_u32()?;
        let npos = c.take_u32()? as usize;
        if k as usize != cfg.k() || npos != cfg.q() {
            return Err(StoreError::Corrupt(format!(
                "selection geometry (k={k}, q={npos}) does not match the config (k={}, q={})",
                cfg.k(),
                cfg.q()
            )));
        }
        let mut positions = Vec::with_capacity(npos.min(c.remaining() / 4));
        for _ in 0..npos {
            let pos = c.take_u32()?;
            if pos as usize >= cfg.n {
                return Err(StoreError::Corrupt(format!(
                    "selection position {pos} out of range for N={}",
                    cfg.n
                )));
            }
            positions.push(pos);
        }
        let retrain_threshold = c.take_f64()?;
        let stale_deletes = c.take_u64()?;
        let insert_cursor = c.take_u64()?;
        let wal_generation = c.take_u64()?;

        let valid = c.take_bitvec()?;
        if valid.len() != cfg.m {
            return Err(StoreError::Corrupt(format!(
                "valid bits length {} != M={}",
                valid.len(),
                cfg.m
            )));
        }
        let mut tags = Vec::new();
        for a in 0..cfg.m {
            let t = c.take_bitvec()?;
            if t.len() != cfg.n {
                return Err(StoreError::Corrupt(format!(
                    "tag at address {a} is {} bits, expected N={}",
                    t.len(),
                    cfg.n
                )));
            }
            tags.push(t);
        }
        let mut rows = Vec::new();
        for i in 0..cfg.cl() {
            let r = c.take_bitvec()?;
            if r.len() != cfg.m {
                return Err(StoreError::Corrupt(format!(
                    "weight row {i} is {} bits, expected M={}",
                    r.len(),
                    cfg.m
                )));
            }
            rows.push(r);
        }
        let filter = if version >= 2 {
            let cells_len = c.take_u64()?;
            if cells_len == 0 {
                None // the producer carried no filter; restore rebuilds it
            } else {
                if cells_len > MAX_FILTER_CELLS {
                    return Err(StoreError::Corrupt(format!(
                        "filter cell count {cells_len} out of range"
                    )));
                }
                let mut cells = Vec::with_capacity((cells_len as usize).min(c.remaining() / 4));
                for _ in 0..cells_len {
                    cells.push(c.take_u32()?);
                }
                let keys = c.take_u64()?;
                Some(crate::cam::BankFilter::from_parts(cells, keys).map_err(StoreError::Corrupt)?)
            }
        } else {
            None // v1 image: no filter section existed
        };
        c.finish()?;
        Ok(BankImage {
            cfg,
            positions,
            k,
            rows,
            tags,
            valid,
            filter,
            stale_deletes,
            retrain_threshold,
            insert_cursor,
            wal_generation,
        })
    }

    /// Atomically and durably persist ([`crate::store::atomic_write`]):
    /// tmp file, fsync, rename over `path`, best-effort directory sync.
    pub fn write_to(&self, path: &Path) -> Result<(), StoreError> {
        crate::store::atomic_write(path, &self.encode())
    }

    /// Load and validate a snapshot file.
    pub fn read_from(path: &Path) -> Result<BankImage, StoreError> {
        let data = std::fs::read(path)?;
        Self::decode(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;

    fn populated_engine() -> LookupEngine {
        let mut e = LookupEngine::new(DesignConfig::small_test());
        e.retrain_threshold = 0.0;
        let mut rng = Rng::seed_from_u64(17);
        let tags = TagDistribution::Uniform.sample_distinct(32, 40, &mut rng);
        for t in &tags {
            e.insert(t).unwrap();
        }
        for a in [3usize, 9, 20] {
            e.delete(a).unwrap();
        }
        e
    }

    #[test]
    fn image_roundtrips_through_bytes_bit_identically() {
        let mut original = populated_engine();
        let image = BankImage::from_engine(&original);
        let decoded = BankImage::decode(&image.encode()).unwrap();
        assert_eq!(decoded, image);
        let mut restored = decoded.into_engine().unwrap();
        assert_eq!(restored.occupancy(), original.occupancy());
        assert_eq!(restored.stale_delete_count(), original.stale_delete_count());
        assert_eq!(restored.insert_cursor(), original.insert_cursor());
        let mut rng = Rng::seed_from_u64(18);
        let probes = TagDistribution::Uniform.sample_distinct(32, 32, &mut rng);
        for t in &probes {
            assert_eq!(original.lookup(t).unwrap(), restored.lookup(t).unwrap());
        }
    }

    #[test]
    fn file_roundtrip_is_atomic_and_identical() {
        let dir = std::env::temp_dir().join(format!("cscam-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bank.snap");
        let engine = populated_engine();
        let image = BankImage::from_engine(&engine);
        image.write_to(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp file renamed away");
        assert_eq!(BankImage::read_from(&path).unwrap(), image);
    }

    #[test]
    fn snapshot_carries_the_filter_and_restores_it_verbatim() {
        let engine = populated_engine();
        let image = BankImage::from_engine(&engine);
        assert!(image.filter.is_some(), "a live capture always carries the filter");
        let decoded = BankImage::decode(&image.encode()).unwrap();
        assert_eq!(decoded.filter, image.filter);
        let restored = decoded.into_engine().unwrap();
        assert_eq!(restored.search_state().filter(), engine.search_state().filter());
    }

    /// Re-stamp a v2 image without its filter section as a version-1 file:
    /// strip the trailing `[cell_count=0 u64]` the None-filter encoder
    /// writes, set the header version to 1 and recompute length + checksum.
    fn as_v1_bytes(image: &BankImage) -> Vec<u8> {
        let mut no_filter = image.clone();
        no_filter.filter = None;
        let v2 = no_filter.encode();
        let payload = &v2[SNAPSHOT_HEADER_LEN..v2.len() - 8];
        let mut out = Vec::with_capacity(SNAPSHOT_HEADER_LEN + payload.len());
        out.extend_from_slice(&SNAPSHOT_MAGIC);
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&crate::util::hash::fnv1a_bytes(payload).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn v1_snapshot_still_loads_and_rebuilds_an_identical_filter() {
        let mut engine = populated_engine();
        let image = BankImage::from_engine(&engine);
        let decoded = BankImage::decode(&as_v1_bytes(&image)).unwrap();
        assert_eq!(decoded.filter, None, "v1 images carry no filter section");
        let mut restored = decoded.into_engine().unwrap();
        assert_eq!(
            restored.search_state().filter(),
            engine.search_state().filter(),
            "rebuild-on-missing yields the exact writer-maintained filter"
        );
        let mut rng = Rng::seed_from_u64(29);
        let probes = TagDistribution::Uniform.sample_distinct(32, 32, &mut rng);
        for t in &probes {
            assert_eq!(engine.lookup(t).unwrap(), restored.lookup(t).unwrap());
        }
    }

    #[test]
    fn corrupt_filter_section_is_a_typed_error() {
        let image = BankImage::from_engine(&populated_engine());
        let good = image.encode();
        // the keys counter is the last 8 payload bytes: desync it from the
        // CAM occupancy and restore must refuse
        let mut bad = good.clone();
        let keys_at = bad.len() - 8;
        bad[keys_at] ^= 0xFF;
        // fix up the checksum so only the semantic check can catch it
        let payload = &bad[SNAPSHOT_HEADER_LEN..];
        let sum = crate::util::hash::fnv1a_bytes(payload).to_le_bytes();
        bad[16..24].copy_from_slice(&sum);
        let decoded = BankImage::decode(&bad).unwrap();
        assert!(matches!(decoded.into_engine(), Err(StoreError::Corrupt(_))));
    }

    #[test]
    fn header_tampering_is_a_typed_error() {
        let image = BankImage::from_engine(&populated_engine());
        let good = image.encode();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(BankImage::decode(&bad), Err(StoreError::Corrupt(_))));

        let mut bad = good.clone();
        bad[4] = 99; // version
        assert!(matches!(BankImage::decode(&bad), Err(StoreError::Incompatible(_))));

        let mut bad = good.clone();
        bad[6] = 1; // reserved
        assert!(matches!(BankImage::decode(&bad), Err(StoreError::Corrupt(_))));

        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x01; // payload bit → checksum mismatch
        assert!(matches!(BankImage::decode(&bad), Err(StoreError::Corrupt(_))));

        let mut bad = good.clone();
        bad.push(0); // trailing byte → length mismatch
        assert!(matches!(BankImage::decode(&bad), Err(StoreError::Corrupt(_))));

        assert!(BankImage::decode(&good[..good.len() - 1]).is_err());
        assert!(BankImage::decode(&good[..10]).is_err());
        assert!(BankImage::decode(&[]).is_err());
    }
}
