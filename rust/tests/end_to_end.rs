//! End-to-end system tests: full workloads through the coordinator,
//! non-uniform-input behaviour, shard scale-out, and the paper's §II claim
//! that non-uniformity costs power but never accuracy.

use std::time::Duration;

use cscam::cnn::Selection;
use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, LookupEngine};
use cscam::shard::{PlacementMode, ShardedCam};
use cscam::util::Rng;
use cscam::workload::{AclTrace, QueryMix, TagDistribution, TlbTrace};

#[test]
fn reference_design_full_occupancy_workload() {
    // Fill the full 512-entry reference CAM and serve a hit/miss mix; check
    // hit accounting, ambiguity statistics and the energy band.
    let cfg = DesignConfig::reference();
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(11);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    assert_eq!(engine.occupancy(), cfg.m);

    let mix = QueryMix { hit_ratio: 0.75, zipf_s: 0.0 };
    let mut hits = 0usize;
    let mut energy = 0.0;
    let mut lambda_sum = 0usize;
    let queries = 2_000;
    for _ in 0..queries {
        let (tag, expect) = mix.sample(&stored, cfg.n, &mut rng);
        let out = engine.lookup(&tag).unwrap();
        match expect {
            Some(i) => {
                assert_eq!(out.addr, Some(i));
                hits += 1;
                lambda_sum += out.lambda;
            }
            None => assert_eq!(out.addr, None, "false positive on a random miss"),
        }
        energy += out.energy.total_fj();
    }
    assert!((0.70..0.80).contains(&(hits as f64 / queries as f64)));
    // measured λ on hits ≈ closed form (±10 %)
    let mean_lambda = lambda_sum as f64 / hits as f64;
    let expected = cfg.expected_lambda();
    assert!((mean_lambda - expected).abs() / expected < 0.10, "λ̄ {mean_lambda} vs {expected}");
    // measured per-search energy lands in the paper band (hit-heavy mix)
    let per_bit = energy / queries as f64 / (cfg.m * cfg.n) as f64;
    assert!((0.08..0.16).contains(&per_bit), "measured {per_bit} fJ/bit/search");
}

#[test]
fn tlb_workload_through_server_with_replacement() {
    // A TLB in front of a page table: misses insert (with FIFO replacement
    // once full), hits are served; the CNN stays consistent throughout.
    let cfg = DesignConfig { m: 64, n: 52, zeta: 8, c: 3, l: 4, ..DesignConfig::reference() };
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(5);
    let trace = TlbTrace { n: 52, working_set: 48, p_sequential: 0.15, p_new: 0.01 }
        .generate(3_000, &mut rng)
        .0;

    let mut resident: Vec<Option<cscam::bits::BitVec>> = vec![None; cfg.m];
    let mut next_victim = 0usize;
    let (mut hits, mut misses) = (0usize, 0usize);
    for vpn in &trace {
        let out = engine.lookup(vpn).unwrap();
        match out.addr {
            Some(addr) => {
                hits += 1;
                assert_eq!(resident[addr].as_ref(), Some(vpn), "TLB returned the wrong page");
            }
            None => {
                misses += 1;
                let victim = next_victim;
                next_victim = (next_victim + 1) % cfg.m;
                engine.insert_at(victim, vpn).unwrap();
                resident[victim] = Some(vpn.clone());
            }
        }
    }
    assert!(hits > misses, "locality should make hits dominate: {hits} vs {misses}");
}

#[test]
fn correlated_tags_cost_energy_not_accuracy() {
    // §I/§II-B: non-uniform reduced tags enable more sub-blocks (more
    // energy) but the result stays exact.  Naive contiguous selection on
    // ACL-style tags (constant prefix in the selected window when selecting
    // high bits) must still answer correctly, just less efficiently than
    // the strided selection.
    let cfg = DesignConfig { m: 128, n: 64, zeta: 8, c: 3, l: 4, ..DesignConfig::reference() };
    let mut rng = Rng::seed_from_u64(77);
    let tags = AclTrace { n: cfg.n, prefixes: 4, prefix_len: 40 }.generate(cfg.m, &mut rng);

    // bad: select q bits from the nearly-constant prefix (top of the tag)
    let q = cfg.q();
    let bad = Selection::explicit((cfg.n - q..cfg.n).collect(), cfg.k());
    // good: entropy-driven selection from a sample
    let good = Selection::entropy_greedy(&tags, cfg.n, cfg.c, cfg.k());

    let mut results = Vec::new();
    for sel in [bad, good] {
        let mut engine = LookupEngine::with_selection(cfg.clone(), sel);
        for t in &tags {
            engine.insert(t).unwrap();
        }
        let mut comparisons = 0usize;
        for (i, t) in tags.iter().enumerate() {
            let out = engine.lookup(t).unwrap();
            assert_eq!(out.addr, Some(i), "accuracy must not depend on bit selection");
            comparisons += out.comparisons;
        }
        results.push(comparisons as f64 / tags.len() as f64);
    }
    let (bad_cmp, good_cmp) = (results[0], results[1]);
    assert!(
        bad_cmp > 2.0 * good_cmp,
        "correlated selection must burn more comparisons: bad {bad_cmp} vs good {good_cmp}"
    );
}

#[test]
fn sharded_fleet_scales_capacity() {
    // Four small_test banks behind a tag-hash router: the fleet stores what
    // one macro cannot (total capacity = 4 × 64), and every stored tag stays
    // findable through the routed lookup.
    let cfg = DesignConfig { m: 4 * 64, shards: 4, ..DesignConfig::small_test() };
    let mut cam = ShardedCam::new(&cfg, PlacementMode::TagHash);
    let mut rng = Rng::seed_from_u64(9);
    // more tags than one macro can hold (some banks may fill first: count)
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 3 * 64, &mut rng);
    let mut inserted = 0usize;
    for t in &tags {
        if cam.insert(t).is_ok() {
            inserted += 1;
        }
    }
    assert!(inserted > 64, "sharding must exceed single-macro capacity: {inserted}");
    assert_eq!(cam.occupancy(), inserted);
    let mut found = 0usize;
    for t in &tags {
        if cam.lookup(t).unwrap().addr.is_some() {
            found += 1;
        }
    }
    assert_eq!(found, inserted);
}

#[test]
fn server_under_concurrent_mixed_load() {
    let cfg = DesignConfig::small_test();
    let server = CamServer::new(
        cfg.clone(),
        DecodeBackend::Native,
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) },
    );
    let h = server.spawn();
    let mut rng = Rng::seed_from_u64(31);
    let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 48, &mut rng);
    for t in &tags {
        h.insert(t.clone()).unwrap();
    }
    let mut joins = Vec::new();
    for worker in 0..6 {
        let h = h.clone();
        let tags = tags.clone();
        let n = cfg.n;
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(1000 + worker);
            let mut hits = 0usize;
            for i in 0..200 {
                if i % 10 == 0 {
                    let t = cscam::workload::random_tag(n, &mut rng);
                    let _ = h.lookup(t);
                } else {
                    let t = tags[rng.gen_range(tags.len())].clone();
                    hits += h.lookup(t).unwrap().addr.is_some() as usize;
                }
            }
            hits
        }));
    }
    let total_hits: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total_hits, 6 * 180);
    let m = h.metrics().unwrap();
    assert_eq!(m.lookups, 6 * 200);
    assert!(m.batch_size.mean() >= 1.0);
}
