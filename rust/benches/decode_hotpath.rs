//! Micro-benchmarks of the L3 hot path (see rust/README.md):
//! the native CNN decode (`decode_into`), tag-bit selection, the ζ-group
//! OR, the full engine lookup with the bloom pre-filter on and off, and —
//! with the `pjrt` feature and artifacts present — the batched PJRT decode
//! per-query cost.
//!
//! Perf target: native decode ≥ 10 M lookups/s single-thread at the
//! reference geometry, so the coordinator is never the bottleneck against
//! the modelled 1.4 GHz device.
//!
//! Run: `cargo bench --bench decode_hotpath`
//!
//! Flags (after `--`):
//! * `--quick`      headline rows only, shorter samples (CI smoke);
//! * `--json PATH`  append the headline rows (tagged `decode_hotpath`) to
//!   the `BENCH_*.json` trajectory shared with the other benches.  Row
//!   keys: `prefilter`, `hit_ratio`, `throughput_lps`, `mean_lambda`.
//!
//! The headline pair measures the same single-reader lookup stream twice —
//! once through `LookupEngine::lookup` (slab kernels + bloom pre-filter)
//! and once through `lookup_unfiltered` (slab kernels only, the reference
//! path the bit-identity battery checks against) — so the trajectory
//! records what the pre-filter buys on a miss-bearing mix.

use cscam::bits::BitVec;
use cscam::cnn::{ClusteredNetwork, Selection};
use cscam::config::DesignConfig;
use cscam::coordinator::LookupEngine;
use cscam::util::bench::{black_box, write_bench_json, BenchRecord, BenchTimer};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

fn trained(cfg: &DesignConfig, seed: u64) -> (ClusteredNetwork, Vec<Vec<u16>>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut net = ClusteredNetwork::from_config(cfg);
    let mut idxs = Vec::new();
    for addr in 0..cfg.m {
        let idx: Vec<u16> = (0..cfg.c).map(|_| rng.gen_range(cfg.l) as u16).collect();
        net.train(&idx, addr);
        idxs.push(idx);
    }
    (net, idxs)
}

/// A filled reference bank plus a probe stream with the given hit ratio.
/// Fixed seeds: every run (and both prefilter variants) measures the same
/// tags in the same order.
fn filled_engine(cfg: &DesignConfig, hit_ratio: f64) -> (LookupEngine, Vec<BitVec>) {
    let mut engine = LookupEngine::new(cfg.clone());
    let mut rng = Rng::seed_from_u64(4);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    for t in &stored {
        engine.insert(t).unwrap();
    }
    let mix = QueryMix { hit_ratio, zipf_s: 0.0 };
    let probes: Vec<BitVec> =
        (0..1024).map(|_| mix.sample(&stored, cfg.n, &mut rng).0).collect();
    (engine, probes)
}

/// One headline row: the full engine lookup (selection + pre-filter +
/// decode + CAM search + energy accounting) on a mixed stream, with the
/// bloom pre-filter consulted (`prefilter = true`) or bypassed.
fn run_headline(
    timer: &BenchTimer,
    cfg: &DesignConfig,
    hit_ratio: f64,
    prefilter: bool,
) -> BenchRecord {
    let (mut engine, probes) = filled_engine(cfg, hit_ratio);
    let state = if prefilter { "on" } else { "off" };
    let name = format!("decode_hotpath/prefilter={state}/hit{:.0}", hit_ratio * 100.0);
    let mut lambda_sum = 0u64;
    let mut served = 0u64;
    let mut i = 0usize;
    let r = timer.run(&name, || {
        i = (i + 1) % probes.len();
        let out = if prefilter {
            engine.lookup(&probes[i]).unwrap()
        } else {
            engine.lookup_unfiltered(&probes[i]).unwrap()
        };
        lambda_sum += out.lambda as u64;
        served += 1;
        black_box(out.comparisons)
    });
    println!(
        "   → {:.2} M lookups/s (prefilter {state}, {:.0} % hit mix)",
        r.per_second() / 1e6,
        hit_ratio * 100.0
    );
    let mut rec = BenchRecord::new(name);
    rec.push("prefilter", prefilter as u64 as f64);
    rec.push("hit_ratio", hit_ratio);
    rec.push("throughput_lps", r.per_second());
    rec.push("mean_lambda", lambda_sum as f64 / served.max(1) as f64);
    rec
}

fn main() -> anyhow::Result<()> {
    // `cargo bench ... -- FLAGS` forwards FLAGS here (harness = false)
    let args = Args::parse(std::env::args().skip(1), &["quick"])?;
    args.check_known(&["quick", "json"])?;
    let quick = args.flag("quick");
    let timer = if quick {
        BenchTimer::new(
            std::time::Duration::from_millis(60),
            std::time::Duration::from_millis(60),
            4,
        )
    } else {
        BenchTimer::default()
    };
    let cfg = DesignConfig::reference();

    if !quick {
        // 1. native GD decode, reference geometry (512 entries, c=3)
        let (net, idxs) = trained(&cfg, 1);
        let mut act = BitVec::zeros(cfg.m);
        let mut en = BitVec::zeros(cfg.beta());
        let mut i = 0usize;
        let r = timer.run("cnn_decode_into/M=512,c=3,l=8,zeta=8", || {
            i = (i + 1) % idxs.len();
            net.decode_into(&idxs[i], &mut act, &mut en)
        });
        println!(
            "   → {:.1} M decodes/s (target ≥ 10 M/s: {})",
            r.per_second() / 1e6,
            if r.per_second() >= 10e6 { "PASS" } else { "MISS" }
        );

        // 2. geometry scaling of the decode
        for (m, c) in [(1024usize, 3usize), (4096, 3), (512, 6)] {
            let big = DesignConfig { m, c, zeta: 8, ..DesignConfig::reference() };
            let (net, idxs) = trained(&big, 2);
            let mut act = BitVec::zeros(big.m);
            let mut en = BitVec::zeros(big.beta());
            let mut i = 0usize;
            timer.run(&format!("cnn_decode_into/M={m},c={c}"), || {
                i = (i + 1) % idxs.len();
                net.decode_into(&idxs[i], &mut act, &mut en)
            });
        }

        // 3. tag-bit selection (strided), hot-path variant
        let sel = Selection::strided(cfg.n, cfg.c, cfg.k());
        let mut rng = Rng::seed_from_u64(3);
        let tags: Vec<BitVec> =
            (0..256).map(|_| cscam::workload::random_tag(cfg.n, &mut rng)).collect();
        let mut buf = Vec::new();
        let mut i = 0usize;
        timer.run("selection_apply_into/N=128,q=9", || {
            i = (i + 1) % tags.len();
            sel.apply_into(&tags[i], &mut buf);
            buf.len()
        });
    }

    // 4. headline pair: full engine lookup, pre-filter on vs off, on the
    //    same 50 % hit mix (misses are where the filter earns its keep)
    let mut records = Vec::new();
    for prefilter in [true, false] {
        records.push(run_headline(&timer, &cfg, 0.5, prefilter));
    }

    // 5. PJRT batched decode (per-query amortized), if built with the
    //    `pjrt` feature and artifacts exist
    if !quick {
        pjrt_decode_benches(&timer);
    }

    if let Some(path) = args.get("json") {
        write_bench_json(std::path::Path::new(path), "decode_hotpath", &records)?;
        println!("\nappended {} 'decode_hotpath' trajectory rows to {path}", records.len());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_decode_benches(timer: &BenchTimer) {
    use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};

    if !artifacts_available() {
        println!("(skipping pjrt_decode benches: run `make artifacts`)");
        return;
    }
    let mut store = ArtifactStore::load(&default_artifact_dir()).expect("artifacts");
    let mcfg = store.manifest().config.clone();
    let acfg = DesignConfig {
        m: mcfg.m,
        zeta: mcfg.zeta,
        c: mcfg.c,
        l: mcfg.l,
        ..DesignConfig::reference()
    };
    let (net, idxs) = trained(&acfg, 5);
    store.set_weights(&net.weight_rows()).expect("weights");
    for &batch in &store.batch_sizes() {
        let queries: Vec<Vec<u16>> = (0..batch).map(|i| idxs[i % idxs.len()].clone()).collect();
        let r = timer.run(&format!("pjrt_decode/batch={batch}"), || {
            store.decode(&queries).unwrap().lambda.len()
        });
        println!(
            "   → {:.2} µs/query amortized at batch {batch}",
            r.mean_ns / 1000.0 / batch as f64
        );
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_decode_benches(_timer: &BenchTimer) {
    println!("(skipping pjrt_decode benches: built without the `pjrt` feature)");
}
