//! END-TO-END VALIDATION DRIVER (requires `--features pjrt`).
//!
//! Proves all three layers compose on a real workload:
//!
//!   L1/L2  Pallas GD kernel + JAX decode graph, AOT-lowered by
//!          `make artifacts` to HLO text — loaded and executed here via
//!          PJRT (Python is NOT running);
//!   L3     the Rust coordinator: dynamic batcher, CAM model, insert/delete,
//!          metrics.
//!
//! The driver loads the artifacts, trains the reference 512-entry design
//! through the PJRT train graph, serves a 20 000-lookup hit/miss mix
//! through both backends (native and PJRT decode), verifies they agree
//! exactly, and reports latency/throughput/energy.
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example end_to_end_serve`
//!
//! Flags: `--shards S` (default 1) adds a sharded-fleet section — the same
//! workload through `S` native-decode banks behind the scatter-gather
//! router — and `--placement hash|prefix|broadcast` picks the routing mode
//! (the PJRT backend itself stays single-bank: the artifacts are
//! AOT-compiled for one geometry).

use std::time::Duration;

use cscam::config::DesignConfig;
use cscam::coordinator::{BatchPolicy, CamServer, DecodeBackend, LookupEngine};
use cscam::runtime::{artifacts_available, default_artifact_dir, ArtifactStore};
use cscam::shard::{PlacementMode, ShardedCamServer};
use cscam::util::cli::Args;
use cscam::util::Rng;
use cscam::workload::{QueryMix, TagDistribution};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    args.check_known(&["shards", "placement"])?;
    let shards: usize = args.get_parse("shards", 1)?;
    let placement = args.get("placement").unwrap_or("hash").to_string();
    if !artifacts_available() {
        anyhow::bail!("no artifacts found — run `make artifacts` first");
    }
    let store = ArtifactStore::load(&default_artifact_dir())?;
    println!("# end-to-end serve — three-layer validation");
    println!("artifacts: {:?}", store);
    let mcfg = store.manifest().config.clone();
    let cfg = DesignConfig {
        m: mcfg.m,
        zeta: mcfg.zeta,
        c: mcfg.c,
        l: mcfg.l,
        ..DesignConfig::reference()
    };

    // Populate two identical engines (shared RNG seed ⇒ identical tables).
    let mut rng = Rng::seed_from_u64(424242);
    let stored = TagDistribution::Uniform.sample_distinct(cfg.n, cfg.m, &mut rng);
    let mut engine_native = LookupEngine::new(cfg.clone());
    let mut engine_pjrt = LookupEngine::new(cfg.clone());
    for t in &stored {
        engine_native.insert(t)?;
        engine_pjrt.insert(t)?;
    }

    let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) };
    let native = CamServer::with_engine(engine_native, DecodeBackend::Native, policy).spawn();
    let pjrt = CamServer::with_engine(engine_pjrt, DecodeBackend::pjrt(store), policy).spawn();

    // The workload: 20 000 lookups, 90 % hits, from 8 client threads.
    let lookups = 20_000;
    let threads = 8;
    let mix = QueryMix { hit_ratio: 0.9, zipf_s: 0.8 };
    let mut per_thread: Vec<Vec<cscam::bits::BitVec>> = vec![Vec::new(); threads];
    for i in 0..lookups {
        let (tag, _) = mix.sample(&stored, cfg.n, &mut rng);
        per_thread[i % threads].push(tag);
    }

    // Cross-check a sample of queries between the two backends first.
    let mut agree = 0usize;
    for t in per_thread[0].iter().take(512) {
        let a = native.lookup(t.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
        let b = pjrt.lookup(t.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
        assert_eq!(a.addr, b.addr, "backend disagreement");
        assert_eq!(a.lambda, b.lambda, "λ disagreement");
        agree += 1;
    }
    println!("\nbackend agreement: {agree}/512 sampled queries identical (addr + λ)");

    for (name, handle) in [("native", &native), ("pjrt", &pjrt)] {
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for qs in per_thread.clone() {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut hits = 0usize;
                for t in qs {
                    hits += h.lookup(t).expect("lookup").addr.is_some() as usize;
                }
                hits
            }));
        }
        let hits: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        let wall = t0.elapsed();
        let m = handle.metrics().expect("metrics");
        println!("\n## backend = {name}");
        println!("  {}", m.summary(cfg.m, cfg.n));
        println!(
            "  hits {}/{} | throughput {:.0} lookups/s | wall {:.3} s | mean batch {:.1} | p50 {} ns p99 {} ns",
            hits,
            lookups,
            lookups as f64 / wall.as_secs_f64(),
            wall.as_secs_f64(),
            m.batch_size.mean(),
            m.host_latency_ns.quantile(0.5),
            m.host_latency_ns.quantile(0.99),
        );
        println!(
            "  modelled CAM energy: {:.4} fJ/bit/search (paper: 0.124) — λ̄ {:.3}, blocks̄ {:.3}",
            m.energy_per_bit(cfg.m, cfg.n),
            m.lambda.mean(),
            m.enabled_blocks.mean()
        );
    }

    // Optional scale-out section: the same workload through a sharded
    // fleet of native-decode banks.
    if shards > 1 {
        let mut fleet_cfg = cfg.clone();
        fleet_cfg.shards = shards;
        fleet_cfg.validate()?;
        let mode = match placement.as_str() {
            "hash" => PlacementMode::TagHash,
            "prefix" => PlacementMode::learned(shards, &stored, cfg.n),
            "broadcast" => PlacementMode::Broadcast,
            other => anyhow::bail!("unknown --placement '{other}' (hash|prefix|broadcast)"),
        };
        let fleet = ShardedCamServer::new(&fleet_cfg, mode, policy).spawn();
        let mut fleet_stored = 0usize;
        for t in &stored {
            if fleet.insert(t.clone()).is_ok() {
                fleet_stored += 1;
            }
        }
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for qs in per_thread.clone() {
            let h = fleet.clone();
            joins.push(std::thread::spawn(move || {
                let mut hits = 0usize;
                for t in qs {
                    hits += h.lookup(t).expect("lookup").addr.is_some() as usize;
                }
                hits
            }));
        }
        let hits: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        let wall = t0.elapsed();
        let fm = fleet.fleet_metrics().expect("metrics");
        println!(
            "\n## sharded fleet — {shards} banks × {} entries, native decode, placement={placement}",
            fleet_cfg.per_bank().m
        );
        println!("  stored {fleet_stored}/{} (banks fill binomially under hash)", stored.len());
        println!("  {}", fm.summary(fleet_cfg.per_bank().m, fleet_cfg.n));
        println!(
            "  hits {}/{} | throughput {:.0} lookups/s | wall {:.3} s | hottest bank {} ({:.1} %)",
            hits,
            lookups,
            lookups as f64 / wall.as_secs_f64(),
            wall.as_secs_f64(),
            fm.hottest_bank(),
            100.0 * fm.hot_fraction()
        );
    }

    println!("\nall layers composed: AOT (python, build-time) → PJRT (rust runtime) → coordinator (rust serve loop).");
    Ok(())
}
