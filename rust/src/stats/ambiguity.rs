//! Ambiguity (λ) estimators — closed form and Monte Carlo — behind Fig. 3.
//!
//! Model: M entries stored with i.i.d. uniform q-bit reduced tags, each
//! trained to its own P_II neuron; the query equals one stored entry's
//! reduced tag.  A P_II neuron activates iff its entry's reduced tag matches
//! the query in *every* cluster — i.e. iff the full q-bit reduced tags are
//! equal (each address is trained exactly once, so the per-cluster OR
//! degenerates to the entry's own weight).  Hence
//!
//!   λ = 1 + Binomial(M − 1, 2^(−q)),      E[λ] = 1 + (M − 1)/2^q.
//!
//! Fig. 3 plots E[#required comparisons] against q for two CAM sizes with
//! one independently-enabled entry per neuron (the ζ = 1 view); with
//! grouping, comparisons = ζ · #activated blocks.

use crate::cnn::ClusteredNetwork;
use crate::util::Rng;

/// Closed-form E\[λ\] for uniform reduced tags (stored-tag query).
pub fn expected_lambda(m: usize, q: usize) -> f64 {
    1.0 + (m as f64 - 1.0) / 2f64.powi(q as i32)
}

/// Closed-form E\[#comparisons\] with ζ-row sub-blocks:
/// ζ × E\[#activated blocks\].
pub fn expected_comparisons(m: usize, q: usize, zeta: usize) -> f64 {
    let extras = expected_lambda(m, q) - 1.0;
    let blocks = 1.0 + extras * (1.0 - (zeta as f64 - 1.0) / (m as f64 - 1.0));
    zeta as f64 * blocks
}

/// A Monte-Carlo λ estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LambdaEstimate {
    /// Mean λ over all trials.
    pub mean_lambda: f64,
    /// Mean number of activated sub-blocks.
    pub mean_blocks: f64,
    /// Mean number of comparisons (ζ × blocks).
    pub mean_comparisons: f64,
    /// Number of query trials.
    pub trials: usize,
}

/// Monte-Carlo estimate of λ through the *real* CNN code path: train a
/// [`ClusteredNetwork`] with M uniform reduced tags, decode stored tags.
///
/// `q` is split into `q` clusters of 1 bit (l = 2) — the ambiguity law
/// depends only on q, not on the (c, l) split (see module docs), and this
/// split is valid for every q.  `trials` queries are drawn by re-sampling
/// stored entries (fresh networks every `m` queries so the tag sets vary).
pub fn simulate_lambda(
    m: usize,
    q: usize,
    zeta: usize,
    trials: usize,
    rng: &mut Rng,
) -> LambdaEstimate {
    assert!(q >= 1 && m >= 1 && trials >= 1);
    let mut sum_lambda = 0.0;
    let mut sum_blocks = 0.0;
    let mut done = 0usize;

    let mut act = crate::bits::BitVec::zeros(m);
    let mut enables = crate::bits::BitVec::zeros(m / zeta);

    while done < trials {
        // fresh random tag set
        let tags: Vec<Vec<u16>> =
            (0..m).map(|_| (0..q).map(|_| rng.gen_range(2) as u16).collect()).collect();
        let mut net = ClusteredNetwork::new(q, 2, m, zeta);
        for (addr, t) in tags.iter().enumerate() {
            net.train(t, addr);
        }
        let batch = (trials - done).min(m);
        for _ in 0..batch {
            let probe = &tags[rng.gen_range(m)];
            let lambda = net.decode_into(probe, &mut act, &mut enables);
            sum_lambda += lambda as f64;
            sum_blocks += enables.count_ones() as f64;
        }
        done += batch;
    }

    LambdaEstimate {
        mean_lambda: sum_lambda / done as f64,
        mean_blocks: sum_blocks / done as f64,
        mean_comparisons: zeta as f64 * sum_blocks / done as f64,
        trials: done,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn closed_form_reference_point() {
        // Table I: M=512, q=9 → E(λ) ≈ 2 activations, i.e. E(ambiguities)=1.
        let e = expected_lambda(512, 9);
        assert!((e - 1.998).abs() < 0.01);
    }

    #[test]
    fn closed_form_limits() {
        assert!((expected_lambda(512, 30) - 1.0).abs() < 1e-6, "large q → no ambiguity");
        assert!(expected_lambda(512, 1) > 250.0, "tiny q → ~M/2 collisions");
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let mut rng = Rng::seed_from_u64(42);
        for (m, q) in [(128usize, 7usize), (256, 9), (512, 9)] {
            let est = simulate_lambda(m, q, 1, 20_000, &mut rng);
            let exp = expected_lambda(m, q);
            let rel = (est.mean_lambda - exp).abs() / exp;
            assert!(rel < 0.05, "M={m} q={q}: sim {} vs closed {exp}", est.mean_lambda);
        }
    }

    #[test]
    fn comparisons_account_for_block_grouping() {
        let mut rng = Rng::seed_from_u64(7);
        let est = simulate_lambda(512, 9, 8, 20_000, &mut rng);
        let exp = expected_comparisons(512, 9, 8);
        let rel = (est.mean_comparisons - exp).abs() / exp;
        assert!(rel < 0.05, "sim {} vs closed {exp}", est.mean_comparisons);
        // ~2 blocks of 8 rows each at the reference point
        assert!((15.0..17.0).contains(&est.mean_comparisons));
    }

    #[test]
    fn zeta_one_comparisons_equal_lambda() {
        let mut rng = Rng::seed_from_u64(3);
        let est = simulate_lambda(128, 8, 1, 5_000, &mut rng);
        assert!((est.mean_comparisons - est.mean_lambda).abs() < 1e-9);
    }

    #[test]
    fn fig3_monotone_in_q() {
        let mut rng = Rng::seed_from_u64(9);
        let mut prev = f64::INFINITY;
        for q in [6usize, 8, 10, 12] {
            let est = simulate_lambda(256, q, 1, 8_000, &mut rng);
            assert!(est.mean_lambda < prev, "q={q}");
            prev = est.mean_lambda;
        }
    }
}
