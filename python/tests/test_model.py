"""L2 correctness: decode/train/add_entry graphs, geometry, and the paper's
statistical claims (E(λ) vs q closed form, §II-B / Fig. 3)."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.model import CnnConfig, add_entry, decode, local_decode, train


def _random_entries(rng, cfg, entries):
    idx = rng.integers(0, cfg.l, size=(entries, cfg.c)).astype(np.int32)
    addr = np.arange(entries, dtype=np.int32)
    return jnp.asarray(idx), jnp.asarray(addr)


class TestConfig:
    def test_reference_design_point(self):
        """Table I: M=512, ζ=8 → β=64; c=3, l=8 → q=9."""
        cfg = CnnConfig(m=512, c=3, l=8, zeta=8)
        assert cfg.q == 9
        assert cfg.beta == 64
        assert cfg.cl == 24

    def test_rejects_bad_geometry(self):
        with pytest.raises(ValueError):
            CnnConfig(m=100, zeta=8)
        with pytest.raises(ValueError):
            CnnConfig(l=6)

    @pytest.mark.parametrize("c,l,q", [(1, 2, 1), (2, 4, 4), (3, 8, 9), (4, 16, 16)])
    def test_q_formula(self, c, l, q):
        assert CnnConfig(m=64, c=c, l=l, zeta=4).q == q


class TestLocalDecode:
    def test_one_hot_per_cluster(self):
        cfg = CnnConfig(m=64, c=3, l=8, zeta=4)
        idx = jnp.asarray([[0, 7, 3], [5, 5, 5]], dtype=jnp.int32)
        u = np.asarray(local_decode(idx, cfg))
        assert u.shape == (2, 24)
        # exactly one activation per cluster
        assert (u.reshape(2, 3, 8).sum(-1) == 1).all()
        assert u[0, 0] == 1 and u[0, 8 + 7] == 1 and u[0, 16 + 3] == 1


class TestTrainDecode:
    def test_roundtrip_finds_entry(self):
        cfg = CnnConfig(m=128, c=3, l=8, zeta=8)
        rng = np.random.default_rng(0)
        idx, addr = _random_entries(rng, cfg, cfg.m)
        w = train(idx, addr, cfg)
        enables, lam = decode(idx, w, cfg)
        enables = np.asarray(enables)
        for e in range(cfg.m):
            assert enables[e, int(addr[e]) // cfg.zeta] == 1.0
        assert (np.asarray(lam) >= 1).all()

    def test_untrained_query_may_miss(self):
        """A query whose reduced tag collides with no stored entry enables
        nothing — zero comparisons, the best case for energy."""
        cfg = CnnConfig(m=64, c=2, l=16, zeta=8)
        idx = jnp.asarray([[3, 4]], dtype=jnp.int32)
        w = jnp.zeros((cfg.cl, cfg.m), jnp.float32)
        enables, lam = decode(idx, w, cfg)
        assert np.asarray(enables).sum() == 0
        assert int(lam[0]) == 0

    def test_add_entry_equals_batch_train(self):
        cfg = CnnConfig(m=64, c=3, l=4, zeta=4)
        rng = np.random.default_rng(1)
        idx, addr = _random_entries(rng, cfg, 32)
        w_batch = np.asarray(train(idx, addr, cfg))
        w_inc = jnp.zeros((cfg.cl, cfg.m), jnp.float32)
        for e in range(32):
            w_inc = add_entry(w_inc, idx[e], addr[e], cfg)
        np.testing.assert_array_equal(w_batch, np.asarray(w_inc))

    def test_add_entry_idempotent(self):
        cfg = CnnConfig(m=32, c=2, l=4, zeta=4)
        w0 = jnp.zeros((cfg.cl, cfg.m), jnp.float32)
        idx = jnp.asarray([1, 3], dtype=jnp.int32)
        w1 = add_entry(w0, idx, jnp.asarray(5), cfg)
        w2 = add_entry(w1, idx, jnp.asarray(5), cfg)
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))

    def test_weights_monotone_in_entries(self):
        cfg = CnnConfig(m=32, c=2, l=4, zeta=4)
        rng = np.random.default_rng(2)
        idx, addr = _random_entries(rng, cfg, 16)
        w_half = np.asarray(train(idx[:8], addr[:8], cfg))
        # train() lowers with E = idx.shape[0]; keep full set for comparison
        w_full = np.asarray(train(idx, addr, cfg))
        assert (w_full >= w_half).all()


class TestAmbiguityStatistics:
    """Fig. 3 / §II-B: with uniform reduced tags, E(λ) = 1 + (M−1)/2^q for a
    query equal to a stored tag. The paper's design point (M=512, q=9) gives
    E(λ) ≈ 2 ⇒ 'on average only two comparisons'."""

    def test_expected_lambda_matches_closed_form(self):
        cfg = CnnConfig(m=256, c=3, l=8, zeta=8)  # q=9
        rng = np.random.default_rng(42)
        trials = []
        for t in range(8):
            idx = rng.integers(0, cfg.l, size=(cfg.m, cfg.c)).astype(np.int32)
            addr = np.arange(cfg.m, dtype=np.int32)
            w = train(jnp.asarray(idx), jnp.asarray(addr), cfg)
            _, lam = decode(jnp.asarray(idx), w, cfg)
            trials.append(np.asarray(lam).mean())
        measured = float(np.mean(trials))
        expected = 1.0 + (cfg.m - 1) / 2**cfg.q
        assert abs(measured - expected) / expected < 0.05

    def test_lambda_decreases_with_q(self):
        """Fig. 3's monotone shape: more reduced-tag bits → fewer ambiguities."""
        rng = np.random.default_rng(7)
        means = []
        for c in [1, 2, 3]:  # q = 3, 6, 9 with l=8
            cfg = CnnConfig(m=128, c=c, l=8, zeta=8)
            idx = rng.integers(0, cfg.l, size=(cfg.m, cfg.c)).astype(np.int32)
            addr = np.arange(cfg.m, dtype=np.int32)
            w = train(jnp.asarray(idx), jnp.asarray(addr), cfg)
            _, lam = decode(jnp.asarray(idx), w, cfg)
            means.append(np.asarray(lam).mean())
        assert means[0] > means[1] > means[2]
