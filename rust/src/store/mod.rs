//! L6 — durable CAM state: snapshot + write-ahead log per bank, a fleet
//! manifest on top.
//!
//! Everything below this layer is volatile: a bank's tags, trained CNN
//! weight rows and free-slot state live in one engine thread's memory and
//! evaporate on process exit.  This module makes the fleet restartable:
//!
//! * [`wal`] — a per-bank append-only log of Insert/Delete records in
//!   length-prefixed, FNV-1a-checksummed frames, with torn-tail truncation
//!   on replay and a configurable [`FsyncPolicy`];
//! * [`snapshot`] — the full bank image (CAM rows + valid bits, CNN weight
//!   rows including stale superposed weights, design geometry, tag-bit
//!   selection, insert cursor) in a versioned, checksummed file written
//!   atomically (tmp + rename);
//! * [`BankStore`] — the persistence half attached to one bank: records
//!   mutations into the WAL and compacts (snapshot, then truncate the log)
//!   once the log passes [`StoreOptions::compact_bytes`];
//! * [`DurableBank`] — engine + store in one synchronous handle, the
//!   simplest embedding and the unit the recovery tests hammer;
//! * [`FleetManifest`] — the fleet directory's `fleet.kv`: records the
//!   shard count, geometry and placement so a restart refuses an
//!   incompatible layout instead of silently re-homing every stored tag
//!   (for learned-prefix placement the manifest carries the exact bit
//!   positions — re-learning them from a fresh sample would move
//!   ownership and orphan the recovered banks).
//!
//! **Recovery contract**: `recover()` (= reopening) rebuilds engine state
//! bit-identical to the pre-crash engine — the same matches, λ, energy and
//! delay for every tag, because replay re-executes `insert_at`/`delete` in
//! logged order against a bit-exact snapshot base.  A torn final WAL frame
//! is truncated, not fatal.  Corrupt snapshots, logs and manifests surface
//! as typed [`StoreError`]s; no decode path panics on hostile bytes (the
//! `codec_fuzz` battery enforces this).

pub mod snapshot;
pub mod wal;

pub use snapshot::BankImage;
pub use wal::{FsyncPolicy, Wal, WalRecord, WalRecovery, WalStats};

use std::path::{Path, PathBuf};

use crate::bits::BitVec;
use crate::cnn::Selection;
use crate::config::DesignConfig;
use crate::coordinator::engine::{EngineError, LookupEngine, LookupOutcome};
use crate::shard::PlacementMode;
use crate::util::codec::CodecError;

/// Everything that can go wrong in the durability layer.
#[derive(Debug)]
pub enum StoreError {
    /// Filesystem failure (create, write, sync, rename…).
    Io(std::io::Error),
    /// Bytes that violate an on-disk format contract (bad magic, bad
    /// checksum, truncated payload, impossible geometry…).
    Corrupt(String),
    /// Well-formed state this build cannot or must not use: an unknown
    /// format version, or a snapshot/manifest whose geometry or placement
    /// contradicts what the caller asked to open.
    Incompatible(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store i/o error: {e}"),
            StoreError::Corrupt(m) => write!(f, "corrupt store state: {m}"),
            StoreError::Incompatible(m) => write!(f, "incompatible store state: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

impl From<CodecError> for StoreError {
    fn from(e: CodecError) -> Self {
        StoreError::Corrupt(e.0)
    }
}

/// Durability tunables shared by every bank of a fleet.
#[derive(Debug, Clone, Copy)]
pub struct StoreOptions {
    /// When WAL appends reach the disk (they always reach the OS).
    pub fsync: FsyncPolicy,
    /// Compaction threshold: snapshot + truncate once the WAL exceeds
    /// this many bytes (0 disables automatic compaction).
    pub compact_bytes: u64,
}

impl Default for StoreOptions {
    fn default() -> Self {
        StoreOptions { fsync: FsyncPolicy::Never, compact_bytes: 4 << 20 }
    }
}

/// Atomic, durable file write shared by the snapshot and manifest
/// writers: tmp file, fsync, rename over the target, best-effort
/// directory sync.  A crash leaves the old content or the new — never an
/// empty or torn file.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        std::io::Write::write_all(&mut f, bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Snapshot file name inside a bank directory.
pub const SNAPSHOT_FILE: &str = "snapshot.bin";

/// WAL file name inside a bank directory.
pub const WAL_FILE: &str = "wal.log";

/// Fleet manifest file name inside a fleet data directory.
pub const MANIFEST_FILE: &str = "fleet.kv";

/// What a bank recovery found on disk.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// A snapshot file existed and seeded the engine.
    pub snapshot_loaded: bool,
    /// Complete WAL records replayed on top of the base state.
    pub wal_records: usize,
    /// Records discarded because the log's generation predates the
    /// snapshot's — a crash landed between the snapshot rename and the WAL
    /// reset, so the snapshot already contains them (replaying would
    /// double-apply every insert and break bit-identical recovery).
    pub discarded_records: usize,
    /// Bytes discarded from a torn/corrupt WAL tail (0 on a clean open).
    pub truncated_bytes: u64,
    /// Live entries after recovery.
    pub occupancy: usize,
}

/// Apply one logged mutation to an engine — recovery replay and the
/// replica apply path ([`crate::repl`]) share this one definition, so a
/// shipped record cannot mean something different on the two sides.  A
/// record the engine rejects means the log belongs to a different
/// geometry — refuse loudly rather than recover a wrong bank.
pub fn apply_record(engine: &mut LookupEngine, rec: &WalRecord) -> Result<(), StoreError> {
    match rec {
        WalRecord::Insert { addr, tag } => {
            engine.insert_at(*addr as usize, tag).map_err(|e| {
                StoreError::Incompatible(format!("WAL insert at address {addr} rejected: {e}"))
            })
        }
        WalRecord::Delete { addr } => engine.delete(*addr as usize).map_err(|e| {
            StoreError::Incompatible(format!("WAL delete at address {addr} rejected: {e}"))
        }),
    }
}

/// The persistence half of one bank: the WAL handle, the snapshot path and
/// the compaction policy.  [`crate::coordinator::CamServer`] carries one of
/// these on its engine thread (mutations are logged in the same barrier
/// that applies them, *before* the acknowledgement is sent);
/// [`DurableBank`] pairs one with an engine for synchronous use.
pub struct BankStore {
    dir: PathBuf,
    wal: Wal,
    opts: StoreOptions,
}

impl BankStore {
    /// Open a bank directory (creating it if absent), recover the engine
    /// (snapshot base + WAL replay, torn tail truncated), and return the
    /// store positioned for logging.  `make_engine` builds the initial
    /// engine when no snapshot exists; it must match `cfg` — a snapshot
    /// with different geometry is refused as [`StoreError::Incompatible`].
    pub fn open(
        dir: &Path,
        opts: StoreOptions,
        cfg: &DesignConfig,
        make_engine: impl FnOnce() -> LookupEngine,
    ) -> Result<(BankStore, LookupEngine, RecoveryReport), StoreError> {
        std::fs::create_dir_all(dir)?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let (mut engine, snapshot_loaded, snap_gen) = if snap_path.exists() {
            let image = BankImage::read_from(&snap_path)?;
            if image.cfg != *cfg {
                return Err(StoreError::Incompatible(format!(
                    "snapshot geometry (M={}, N={}, ζ={}, c={}, l={}) does not match the \
                     requested design point (M={}, N={}, ζ={}, c={}, l={})",
                    image.cfg.m,
                    image.cfg.n,
                    image.cfg.zeta,
                    image.cfg.c,
                    image.cfg.l,
                    cfg.m,
                    cfg.n,
                    cfg.zeta,
                    cfg.c,
                    cfg.l
                )));
            }
            let gen = image.wal_generation;
            (image.into_engine()?, true, gen)
        } else {
            let engine = make_engine();
            assert_eq!(engine.config(), cfg, "factory engine must match the requested config");
            (engine, false, 0)
        };
        let (mut wal, records, wrec) = Wal::open(&dir.join(WAL_FILE), opts.fsync)?;
        let mut wal_records = 0usize;
        let mut discarded_records = 0usize;
        match wal.generation().cmp(&snap_gen) {
            std::cmp::Ordering::Equal => {
                wal_records = records.len();
                for rec in records {
                    apply_record(&mut engine, &rec)?;
                }
            }
            std::cmp::Ordering::Less => {
                // crash between the snapshot rename and the WAL reset:
                // every record in this log is already inside the snapshot;
                // replaying would double-apply it.  Finish the interrupted
                // compaction instead.
                discarded_records = records.len();
                wal.reset(snap_gen)?;
            }
            std::cmp::Ordering::Greater => {
                return Err(StoreError::Incompatible(format!(
                    "WAL generation {} is newer than the snapshot's {snap_gen} — the \
                     snapshot is missing or was rolled back, so the log cannot be \
                     replayed against a base it never extended",
                    wal.generation()
                )));
            }
        }
        let report = RecoveryReport {
            snapshot_loaded,
            wal_records,
            discarded_records,
            truncated_bytes: wrec.truncated_bytes,
            occupancy: engine.occupancy(),
        };
        Ok((BankStore { dir: dir.to_path_buf(), wal, opts }, engine, report))
    }

    /// Log an applied insert (called before the mutation is acknowledged).
    /// Serializes straight from the borrowed tag — no clone on the write
    /// hot path.
    pub fn record_insert(&mut self, addr: usize, tag: &BitVec) -> Result<(), StoreError> {
        self.wal.append_insert(addr as u64, tag)
    }

    /// Log an applied delete (called before the mutation is acknowledged).
    pub fn record_delete(&mut self, addr: usize) -> Result<(), StoreError> {
        self.wal.append(&WalRecord::Delete { addr: addr as u64 })
    }

    /// Snapshot `engine` and reset the WAL — the log's records are now
    /// redundant with the image.  The generation makes the two-step
    /// sequence crash-safe: the snapshot lands first, stamped `g+1`, then
    /// the log resets to `g+1`; a crash between the two leaves a log whose
    /// generation is older than the snapshot's, which [`Self::open`]
    /// discards instead of double-replaying (replay is *not* idempotent —
    /// `insert_at` over a live slot inflates the stale-delete counter and
    /// can fire a spurious retrain).
    pub fn compact(&mut self, engine: &LookupEngine) -> Result<(), StoreError> {
        let next = self.wal.generation() + 1;
        let mut image = BankImage::from_engine(engine);
        image.wal_generation = next;
        image.write_to(&self.dir.join(SNAPSHOT_FILE))?;
        if let Err(e) = self.wal.reset(next) {
            // The snapshot is already in place: any append accepted onto
            // the still-old-generation log from here on would be discarded
            // at recovery despite its acknowledgement.  Refuse them all
            // until a retried compaction resets the log successfully.
            self.wal.poison();
            return Err(e);
        }
        Ok(())
    }

    /// Compact if the WAL has outgrown [`StoreOptions::compact_bytes`].
    /// Returns whether a compaction ran.
    pub fn maybe_compact(&mut self, engine: &LookupEngine) -> Result<bool, StoreError> {
        if self.opts.compact_bytes > 0 && self.wal.len_bytes() > self.opts.compact_bytes {
            self.compact(engine)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Fsync the WAL regardless of policy.
    pub fn flush(&mut self) -> Result<(), StoreError> {
        self.wal.sync()
    }

    /// Install a transferred [`BankImage`] as this bank's new base state:
    /// the image is written as the snapshot (atomic tmp + rename), then
    /// the WAL resets to the image's generation — the replica-bootstrap
    /// analogue of [`Self::compact`], with the same crash ordering (a
    /// crash between the two steps leaves an older-generation log that
    /// [`Self::open`] discards instead of double-replaying).
    pub fn install_image(&mut self, image: &BankImage) -> Result<(), StoreError> {
        image.write_to(&self.dir.join(SNAPSHOT_FILE))?;
        if let Err(e) = self.wal.reset(image.wal_generation) {
            // the snapshot is already in place; appends onto the
            // old-generation log would be discarded at recovery
            self.wal.poison();
            return Err(e);
        }
        Ok(())
    }

    /// The WAL's current generation (the snapshot lineage it extends) —
    /// the generation half of a log-shipping cursor ([`wal::tail_wal`]).
    pub fn wal_generation(&self) -> u64 {
        self.wal.generation()
    }

    /// Current WAL length in bytes (compaction trigger, test probe; also
    /// the offset half of a log-shipping cursor).
    pub fn wal_len_bytes(&self) -> u64 {
        self.wal.len_bytes()
    }

    /// Cumulative WAL append/fsync accounting (see [`WalStats`]) — the
    /// feed behind the `cscam_wal_*` series of the metrics exposition.
    pub fn wal_stats(&self) -> &WalStats {
        self.wal.stats()
    }

    /// The bank directory this store logs into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// One engine plus its persistence, behind a synchronous API: the simplest
/// durable embedding (tests, single-threaded tools).  The threaded serving
/// stack wires the same [`BankStore`] through
/// [`crate::coordinator::CamServer`] instead.
pub struct DurableBank {
    engine: LookupEngine,
    store: BankStore,
}

impl DurableBank {
    /// Open (or create) a durable bank at `dir` for design point `cfg`.
    /// Reopening a populated directory IS the crash-recovery path: state
    /// comes back bit-identical to the engine that wrote it.
    pub fn open(
        dir: &Path,
        cfg: DesignConfig,
        opts: StoreOptions,
    ) -> Result<(DurableBank, RecoveryReport), StoreError> {
        cfg.validate().map_err(|e| StoreError::Incompatible(format!("invalid config: {e}")))?;
        let factory_cfg = cfg.clone();
        let (store, engine, report) =
            BankStore::open(dir, opts, &cfg, move || LookupEngine::new(factory_cfg))?;
        Ok((DurableBank { engine, store }, report))
    }

    /// Insert: applied to the engine, then logged; the address is returned
    /// only after the record reached the OS (per the WAL's write-through
    /// contract) — an acknowledged insert survives a kill.  Failure policy
    /// is [`log_applied_insert`].
    pub fn insert(&mut self, tag: &BitVec) -> Result<usize, EngineError> {
        let addr = self.engine.insert(tag)?;
        log_applied_insert(&mut self.store, &mut self.engine, addr, tag)?;
        Ok(addr)
    }

    /// Delete by address, logged like [`Self::insert`].  Failure policy is
    /// [`log_applied_delete`].
    pub fn delete(&mut self, addr: usize) -> Result<(), EngineError> {
        self.engine.delete(addr)?;
        log_applied_delete(&mut self.store, &self.engine, addr)
    }

    /// Lookup (reads are never logged).
    pub fn lookup(&mut self, tag: &BitVec) -> Result<LookupOutcome, EngineError> {
        self.engine.lookup(tag)
    }

    /// Force a snapshot + WAL truncation now.
    pub fn compact(&mut self) -> Result<(), StoreError> {
        self.store.compact(&self.engine)
    }

    /// Fsync the WAL.
    pub fn flush(&mut self) -> Result<(), StoreError> {
        self.store.flush()
    }

    pub fn occupancy(&self) -> usize {
        self.engine.occupancy()
    }

    pub fn engine(&self) -> &LookupEngine {
        &self.engine
    }

    /// Split into parts (the threaded fleet hands the engine to a
    /// [`crate::coordinator::CamServer`] and keeps the store beside it).
    pub fn into_parts(self) -> (LookupEngine, BankStore) {
        (self.engine, self.store)
    }
}

fn persist_err(e: StoreError) -> EngineError {
    EngineError::Persist(e.to_string())
}

/// The one persist policy for an insert the engine has already applied —
/// shared by [`DurableBank::insert`] and the threaded
/// [`crate::coordinator::CamServer`] barrier so the synchronous and
/// threaded paths cannot drift:
///
/// * a failed log append **rolls the entry back out** of the engine (it
///   must not resurface via a later snapshot, and a client retry must not
///   duplicate it) and surfaces as [`EngineError::Persist`];
/// * a failed *compaction* after a successful append only warns — the
///   record is durable, and failing the acknowledgement would push
///   clients into retrying an already-persisted write (a compaction that
///   leaves the log unsafe poisons it, so later appends fail loudly).
pub fn log_applied_insert(
    store: &mut BankStore,
    engine: &mut LookupEngine,
    addr: usize,
    tag: &BitVec,
) -> Result<(), EngineError> {
    if let Err(e) = store.record_insert(addr, tag) {
        eprintln!("cscam-store: durability failure, rolling the insert back: {e}");
        let _ = engine.delete(addr);
        return Err(persist_err(e));
    }
    if let Err(e) = store.maybe_compact(engine) {
        eprintln!("cscam-store: compaction failure (insert already logged): {e}");
    }
    Ok(())
}

/// The delete half of the policy in [`log_applied_insert`]: no rollback —
/// deletes are idempotent, so a retry converges, and a delete that reaches
/// a later snapshot anyway matches what the client asked for.
pub fn log_applied_delete(
    store: &mut BankStore,
    engine: &LookupEngine,
    addr: usize,
) -> Result<(), EngineError> {
    store.record_delete(addr).map_err(|e| {
        eprintln!("cscam-store: durability failure: {e}");
        persist_err(e)
    })?;
    if let Err(e) = store.maybe_compact(engine) {
        eprintln!("cscam-store: compaction failure (delete already logged): {e}");
    }
    Ok(())
}

// ------------------------------------------------------------- manifest

/// Manifest format version (strict equality, like the snapshot/WAL).
pub const MANIFEST_FORMAT: u32 = 1;

/// The fleet directory's identity card: shard count, geometry and
/// placement.  A restart validates compatibility against it — shard
/// placement is an address-space contract, and silently changing it would
/// re-home every stored tag away from its recovered bank.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetManifest {
    /// Fleet-level design point (`m` = total capacity, `shards` = S).
    pub cfg: DesignConfig,
    /// Placement, with learned-prefix bit positions pinned exactly.
    pub placement: PlacementSpec,
    /// Failover epoch: 0 for a fleet that has never failed over, bumped by
    /// promotion (`cscam promote`, [`crate::repl`]).  A primary refuses
    /// log subscribers from another epoch (wire `ERR_FENCED`), and a
    /// replica refuses to follow a primary from another epoch — so a
    /// rejoining *old* primary is fenced instead of silently diverging.
    /// Deliberately NOT part of [`Self::check_compatible`]: a promoted
    /// data directory must still open.
    pub epoch: u64,
}

/// Serializable placement identity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlacementSpec {
    Hash,
    Broadcast,
    /// Learned-prefix placement: the exact selection that routes tags.
    Prefix { k: usize, positions: Vec<usize> },
}

impl PlacementSpec {
    /// Capture a live placement mode.
    pub fn from_mode(mode: &PlacementMode) -> PlacementSpec {
        match mode {
            PlacementMode::TagHash => PlacementSpec::Hash,
            PlacementMode::Broadcast => PlacementSpec::Broadcast,
            PlacementMode::LearnedPrefix(sel) => {
                PlacementSpec::Prefix { k: sel.k(), positions: sel.positions().to_vec() }
            }
        }
    }

    /// The mode name used in the manifest and in `--placement` flags.
    pub fn kind_name(&self) -> &'static str {
        match self {
            PlacementSpec::Hash => "hash",
            PlacementSpec::Broadcast => "broadcast",
            PlacementSpec::Prefix { .. } => "prefix",
        }
    }

    /// Rebuild the routing mode; `n` bounds the prefix positions.
    pub fn to_mode(&self, n: usize) -> Result<PlacementMode, StoreError> {
        match self {
            PlacementSpec::Hash => Ok(PlacementMode::TagHash),
            PlacementSpec::Broadcast => Ok(PlacementMode::Broadcast),
            PlacementSpec::Prefix { k, positions } => {
                if *k == 0 || positions.is_empty() || positions.len() % k != 0 {
                    return Err(StoreError::Corrupt(format!(
                        "prefix placement with {} positions and k={k}",
                        positions.len()
                    )));
                }
                if let Some(&p) = positions.iter().find(|&&p| p >= n) {
                    return Err(StoreError::Corrupt(format!(
                        "prefix position {p} out of range for N={n}"
                    )));
                }
                Ok(PlacementMode::LearnedPrefix(Selection::explicit(positions.clone(), *k)))
            }
        }
    }
}

impl FleetManifest {
    /// Serialize to the repository's `key = value` text format.
    pub fn to_kv(&self) -> String {
        let mut s = format!("# cscam fleet manifest\nformat = {MANIFEST_FORMAT}\n");
        s.push_str(&self.cfg.to_kv());
        s.push_str(&format!("placement = \"{}\"\n", self.placement.kind_name()));
        if let PlacementSpec::Prefix { k, positions } = &self.placement {
            s.push_str(&format!("prefix_k = {k}\n"));
            let joined: Vec<String> = positions.iter().map(|p| p.to_string()).collect();
            s.push_str(&format!("prefix_positions = {}\n", joined.join(",")));
        }
        s.push_str(&format!("epoch = {}\n", self.epoch));
        s
    }

    /// Parse the manifest text.  Total: malformed text is a typed error.
    pub fn from_kv(text: &str) -> Result<FleetManifest, StoreError> {
        let mut cfg_lines = String::new();
        let mut format: Option<u32> = None;
        let mut placement: Option<String> = None;
        let mut prefix_k: Option<usize> = None;
        let mut prefix_positions: Option<Vec<usize>> = None;
        let mut epoch: Option<u64> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(StoreError::Corrupt(format!(
                    "manifest line {}: expected 'key = value', got '{raw}'",
                    lineno + 1
                )));
            };
            let (key, value) = (key.trim(), value.trim().trim_matches('"'));
            let bad = |what: &str| {
                StoreError::Corrupt(format!("manifest line {}: bad {what}", lineno + 1))
            };
            match key {
                "format" => format = Some(value.parse().map_err(|_| bad("format"))?),
                "m" | "n" | "zeta" | "c" | "l" | "ml_kind" | "node" | "shards" => {
                    cfg_lines.push_str(raw);
                    cfg_lines.push('\n');
                }
                "placement" => placement = Some(value.to_string()),
                "prefix_k" => prefix_k = Some(value.parse().map_err(|_| bad("prefix_k"))?),
                "prefix_positions" => {
                    let mut out = Vec::new();
                    for part in value.split(',').filter(|p| !p.trim().is_empty()) {
                        out.push(part.trim().parse().map_err(|_| bad("prefix_positions"))?);
                    }
                    prefix_positions = Some(out);
                }
                "epoch" => epoch = Some(value.parse().map_err(|_| bad("epoch"))?),
                other => {
                    return Err(StoreError::Corrupt(format!(
                        "manifest line {}: unknown key '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        match format {
            Some(MANIFEST_FORMAT) => {}
            Some(v) => {
                return Err(StoreError::Incompatible(format!(
                    "manifest format {v}, this build reads {MANIFEST_FORMAT}"
                )))
            }
            None => return Err(StoreError::Corrupt("manifest is missing 'format'".into())),
        }
        let cfg = DesignConfig::from_kv(&cfg_lines)
            .map_err(|e| StoreError::Corrupt(format!("manifest geometry: {e}")))?;
        let placement = match placement.as_deref() {
            Some("hash") => PlacementSpec::Hash,
            Some("broadcast") => PlacementSpec::Broadcast,
            Some("prefix") => {
                let k = prefix_k.ok_or_else(|| {
                    StoreError::Corrupt("prefix placement without prefix_k".into())
                })?;
                let positions = prefix_positions.ok_or_else(|| {
                    StoreError::Corrupt("prefix placement without prefix_positions".into())
                })?;
                PlacementSpec::Prefix { k, positions }
            }
            Some(other) => {
                return Err(StoreError::Corrupt(format!("unknown placement '{other}'")))
            }
            None => return Err(StoreError::Corrupt("manifest is missing 'placement'".into())),
        };
        // prefix sanity (bounds against this manifest's own N)
        placement.to_mode(cfg.n)?;
        // `epoch` was introduced with the replication subsystem; a
        // manifest written before it is a never-promoted epoch-0 fleet
        Ok(FleetManifest { cfg, placement, epoch: epoch.unwrap_or(0) })
    }

    /// Load `dir/fleet.kv`.
    pub fn load(dir: &Path) -> Result<FleetManifest, StoreError> {
        let text = std::fs::read_to_string(dir.join(MANIFEST_FILE))?;
        Self::from_kv(&text)
    }

    /// Atomically and durably write `dir/fleet.kv` ([`atomic_write`]) — a
    /// crash can leave the old manifest or the new one, never an
    /// empty/partial file that would refuse every future startup.
    pub fn store(&self, dir: &Path) -> Result<(), StoreError> {
        atomic_write(&dir.join(MANIFEST_FILE), self.to_kv().as_bytes())
    }

    /// Refuse an open whose geometry or placement contradicts this
    /// manifest.  The placement only has to match in *kind* — for
    /// learned-prefix fleets the manifest's recorded positions win over a
    /// freshly learned selection, so routing stays stable across restarts.
    pub fn check_compatible(
        &self,
        cfg: &DesignConfig,
        requested: &PlacementMode,
    ) -> Result<(), StoreError> {
        if self.cfg != *cfg {
            return Err(StoreError::Incompatible(format!(
                "fleet manifest records M={} N={} ζ={} c={} l={} shards={}, \
                 requested M={} N={} ζ={} c={} l={} shards={}",
                self.cfg.m,
                self.cfg.n,
                self.cfg.zeta,
                self.cfg.c,
                self.cfg.l,
                self.cfg.shards,
                cfg.m,
                cfg.n,
                cfg.zeta,
                cfg.c,
                cfg.l,
                cfg.shards
            )));
        }
        let requested_kind = PlacementSpec::from_mode(requested).kind_name();
        if self.placement.kind_name() != requested_kind {
            return Err(StoreError::Incompatible(format!(
                "fleet manifest records '{}' placement, requested '{requested_kind}'",
                self.placement.kind_name()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::TagDistribution;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cscam-store-{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn durable_bank_survives_reopen_bit_identically() {
        let dir = tmp_dir("bank-roundtrip");
        let cfg = DesignConfig::small_test();
        let mut rng = Rng::seed_from_u64(42);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 30, &mut rng);

        let mut reference = LookupEngine::new(cfg.clone());
        {
            let (mut bank, report) =
                DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
            assert_eq!(report, RecoveryReport::default());
            for t in &tags {
                assert_eq!(bank.insert(t).unwrap(), reference.insert(t).unwrap());
            }
            bank.delete(4).unwrap();
            reference.delete(4).unwrap();
            // dropped here without flush or compaction: the crash case
        }
        let (mut bank, report) =
            DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        assert!(!report.snapshot_loaded);
        assert_eq!(report.wal_records, 31);
        assert_eq!(report.occupancy, 29);
        for t in &tags {
            assert_eq!(bank.lookup(t).unwrap(), reference.lookup(t).unwrap());
        }
    }

    #[test]
    fn compaction_snapshots_and_truncates_preserving_state() {
        let dir = tmp_dir("bank-compact");
        let cfg = DesignConfig::small_test();
        let mut rng = Rng::seed_from_u64(43);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 24, &mut rng);

        let mut reference = LookupEngine::new(cfg.clone());
        {
            let (mut bank, _) =
                DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
            for t in tags.iter().take(12) {
                bank.insert(t).unwrap();
                reference.insert(t).unwrap();
            }
            bank.compact().unwrap();
            assert!(dir.join(SNAPSHOT_FILE).exists());
            // post-compaction mutations land in the (now empty) WAL
            for t in tags.iter().skip(12) {
                bank.insert(t).unwrap();
                reference.insert(t).unwrap();
            }
            bank.delete(2).unwrap();
            reference.delete(2).unwrap();
        }
        let (mut bank, report) =
            DurableBank::open(&dir, cfg.clone(), StoreOptions::default()).unwrap();
        assert!(report.snapshot_loaded);
        assert_eq!(report.wal_records, 13);
        for t in &tags {
            assert_eq!(bank.lookup(t).unwrap(), reference.lookup(t).unwrap());
        }
    }

    #[test]
    fn automatic_compaction_fires_past_the_threshold() {
        let dir = tmp_dir("bank-auto-compact");
        let cfg = DesignConfig::small_test();
        let opts = StoreOptions { fsync: FsyncPolicy::Never, compact_bytes: 256 };
        let mut rng = Rng::seed_from_u64(44);
        let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 40, &mut rng);
        let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), opts).unwrap();
        for t in &tags {
            bank.insert(t).unwrap();
        }
        assert!(dir.join(SNAPSHOT_FILE).exists(), "threshold crossing must compact");
        assert!(
            bank.store.wal_len_bytes() <= 256 + wal::WAL_HEADER_LEN + 64,
            "WAL stays near the threshold after compaction"
        );
        drop(bank);
        let (bank, report) = DurableBank::open(&dir, cfg, opts).unwrap();
        assert!(report.snapshot_loaded);
        assert_eq!(bank.occupancy(), 40);
    }

    #[test]
    fn geometry_mismatch_is_refused() {
        let dir = tmp_dir("bank-mismatch");
        let cfg = DesignConfig::small_test();
        {
            let (mut bank, _) = DurableBank::open(&dir, cfg.clone(), StoreOptions::default())
                .unwrap();
            let mut rng = Rng::seed_from_u64(45);
            let tags = TagDistribution::Uniform.sample_distinct(cfg.n, 4, &mut rng);
            for t in &tags {
                bank.insert(t).unwrap();
            }
            bank.compact().unwrap();
        }
        let mut other = cfg.clone();
        other.m = 128;
        assert!(matches!(
            DurableBank::open(&dir, other, StoreOptions::default()),
            Err(StoreError::Incompatible(_))
        ));
    }

    #[test]
    fn manifest_roundtrips_all_placements() {
        let cfg = DesignConfig { shards: 4, ..DesignConfig::reference() };
        for placement in [
            PlacementSpec::Hash,
            PlacementSpec::Broadcast,
            PlacementSpec::Prefix { k: 2, positions: vec![3, 17, 40, 99] },
        ] {
            let m = FleetManifest { cfg: cfg.clone(), placement, epoch: 0 };
            let back = FleetManifest::from_kv(&m.to_kv()).unwrap();
            assert_eq!(back, m);
            back.check_compatible(&cfg, &back.placement.to_mode(cfg.n).unwrap()).unwrap();
        }
    }

    #[test]
    fn manifest_epoch_roundtrips_and_defaults_to_zero() {
        let cfg = DesignConfig { shards: 4, ..DesignConfig::reference() };
        let m = FleetManifest { cfg: cfg.clone(), placement: PlacementSpec::Hash, epoch: 7 };
        let back = FleetManifest::from_kv(&m.to_kv()).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back, m);
        // a promoted (epoch-bumped) directory must still open: the epoch
        // fences subscriptions, never compatibility
        back.check_compatible(&cfg, &PlacementMode::TagHash).unwrap();
        // manifests written before the replication subsystem carry no
        // epoch key and parse as a never-promoted epoch-0 fleet
        let legacy = m.to_kv().lines().filter(|l| !l.starts_with("epoch")).fold(
            String::new(),
            |mut s, l| {
                s.push_str(l);
                s.push('\n');
                s
            },
        );
        assert_eq!(FleetManifest::from_kv(&legacy).unwrap().epoch, 0);
        assert!(FleetManifest::from_kv(&legacy.replace("epoch", "")).is_ok());
        assert!(
            FleetManifest::from_kv(&format!("{legacy}epoch = banana\n")).is_err(),
            "a malformed epoch is corrupt, not silently zero"
        );
    }

    #[test]
    fn manifest_refuses_drifted_fleets() {
        let cfg = DesignConfig { shards: 4, ..DesignConfig::reference() };
        let m = FleetManifest { cfg: cfg.clone(), placement: PlacementSpec::Hash, epoch: 0 };
        let other = DesignConfig { shards: 8, ..cfg.clone() };
        assert!(matches!(
            m.check_compatible(&other, &PlacementMode::TagHash),
            Err(StoreError::Incompatible(_))
        ));
        assert!(matches!(
            m.check_compatible(&cfg, &PlacementMode::Broadcast),
            Err(StoreError::Incompatible(_))
        ));
    }

    #[test]
    fn manifest_parser_is_total_on_garbage() {
        for text in [
            "",
            "format = 1",
            "format = 99\nplacement = \"hash\"\n",
            "format = 1\nplacement = \"warp\"\nm = 512\n",
            "format = 1\nplacement = \"prefix\"\n", // missing prefix keys
            "format = 1\nplacement = \"hash\"\nbogus = 3\n",
            "format = 1\nplacement = \"hash\"\nm = banana\n",
            "no equals sign here",
        ] {
            assert!(FleetManifest::from_kv(text).is_err(), "accepted: {text:?}");
        }
    }
}
