// Fixture fuzz battery: covers every opcode.

fn sample_requests() {
    let _ = Request::Ping;
    let _ = Request::Pong;
}
