//! Cross-file invariant analyzer (`cargo xtask lint`).
//!
//! The cscam tree has several contracts that `rustc` cannot see because
//! they span files: every wire opcode needs an encoder arm, a decoder
//! arm, a fuzz-battery anchor and a README row; every [`EngineError`]
//! variant needs a wire error code in both directions; serving-path code
//! must not panic without a written justification; every
//! `Ordering::Relaxed` needs a rationale; and the `key = value`
//! config/manifest codecs plus the bench-row JSON schema must agree
//! between writer and reader.  This module re-checks all of them from the
//! source text on every `cargo xtask lint` (and from the crate's own unit
//! tests, so `cargo test` fails when the live tree drifts).
//!
//! Scanning is lexical, not syntactic: [`blank_noncode`] strips comments
//! and blanks string/char-literal contents so that brace counting and
//! token searches cannot be fooled by literals, then each check works on
//! that view (or on the raw text where literal contents are the point,
//! as in the kv-key checks).
//!
//! The escape hatch is a `// lint:allow(reason)` comment on the offending
//! line or on the contiguous `//` comment block directly above it.  The
//! reason is mandatory — `lint:allow` without an open parenthesis does
//! not match.  `Ordering::Relaxed` sites need the more specific
//! `lint:allow(relaxed: reason)` form.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One broken invariant, pointing at the file (and line, when the rule
/// is line-anchored) that has to change.
pub struct Violation {
    pub file: PathBuf,
    /// 1-based; 0 for whole-file rules.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file.display(), self.rule, self.msg)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.msg)
        }
    }
}

/// Run every check against the tree rooted at `root` (the directory
/// holding `rust/`).  Returns the empty vec when all invariants hold.
pub fn run(root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    check_opcodes(root, &mut out);
    check_error_codes(root, &mut out);
    check_panic_ban(root, &mut out);
    check_relaxed(root, &mut out);
    check_kv_keys(root, &mut out);
    check_bench_schema(root, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Source-text plumbing

/// Read a repo-relative file; a missing input is itself a violation (the
/// invariant can no longer be checked), and the caller skips the check.
fn read(root: &Path, rel: &str, out: &mut Vec<Violation>) -> Option<String> {
    match fs::read_to_string(root.join(rel)) {
        Ok(s) => Some(s),
        Err(e) => {
            out.push(Violation {
                file: PathBuf::from(rel),
                line: 0,
                rule: "missing-file",
                msg: format!("cannot read lint input: {e}"),
            });
            None
        }
    }
}

/// A per-line view of Rust source with comments removed and string /
/// char-literal contents blanked to spaces (the delimiting quotes
/// survive).  Line count matches `source.split('\n')`.
fn blank_noncode(source: &str) -> Vec<String> {
    enum State {
        Code,
        Str,
        RawStr(usize),
        Chr,
        LineComment,
        BlockComment(usize),
    }
    let chars: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut line = String::new();
    let mut st = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, State::LineComment) {
                st = State::Code;
            }
            lines.push(std::mem::take(&mut line));
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    st = State::Str;
                    line.push('"');
                    i += 1;
                } else if c == 'r' && matches!(next, Some('"') | Some('#')) {
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        st = State::RawStr(hashes);
                        line.push('"');
                        i = j + 1;
                    } else {
                        line.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal ('x', '\n', '"') vs lifetime ('a).
                    let escaped = next == Some('\\');
                    let closed = next.is_some() && chars.get(i + 2) == Some(&'\'');
                    if escaped || closed {
                        st = State::Chr;
                        line.push('\'');
                        i += 1;
                    } else {
                        line.push(c);
                        i += 1;
                    }
                } else {
                    line.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    line.push(' ');
                    // Keep an escaped newline (line continuation) visible
                    // to the top-of-loop handler so line counts stay true.
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    st = State::Code;
                    line.push('"');
                    i += 1;
                } else {
                    line.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    st = State::Code;
                    line.push('"');
                    i += 1 + hashes;
                } else {
                    line.push(' ');
                    i += 1;
                }
            }
            State::Chr => {
                if c == '\\' {
                    line.push(' ');
                    i += 2;
                } else if c == '\'' {
                    st = State::Code;
                    line.push('\'');
                    i += 1;
                } else {
                    line.push(' ');
                    i += 1;
                }
            }
            State::LineComment => {
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(line);
    lines
}

/// String-literal contents of a raw source span, with `\n` / `\t` /
/// `\"` / `\\` unescaped.  Used where the literal text IS the contract
/// (kv keys, JSON schema keys).
fn string_literals(source: &str) -> Vec<String> {
    let chars: Vec<char> = source.chars().collect();
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if in_str {
            if c == '\\' {
                match chars.get(i + 1) {
                    Some('n') => cur.push('\n'),
                    Some('t') => cur.push('\t'),
                    Some(&e) => cur.push(e),
                    None => {}
                }
                i += 2;
            } else if c == '"' {
                out.push(std::mem::take(&mut cur));
                in_str = false;
                i += 1;
            } else {
                cur.push(c);
                i += 1;
            }
        } else if c == '"' {
            in_str = true;
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
        } else if c == '\'' {
            // Skip char literals so '"' cannot open a phantom string.
            let escaped = chars.get(i + 1) == Some(&'\\');
            let closed = chars.get(i + 2) == Some(&'\'');
            if escaped {
                i += 4;
            } else if closed {
                i += 3;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn is_ident(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Whether `text` contains `token` at an identifier boundary: the match
/// may not extend an identifier on either side.  Boundary checks only
/// apply on sides where the token itself starts/ends with an identifier
/// character, so `.unwrap()` and `::Insert` work as expected.
fn has_token(text: &str, token: &str) -> bool {
    token_pos(text, token).is_some()
}

fn token_pos(text: &str, token: &str) -> Option<usize> {
    let bytes = text.as_bytes();
    let tok = token.as_bytes();
    let check_pre = is_ident(tok[0]);
    let check_post = is_ident(tok[tok.len() - 1]);
    let mut from = 0;
    while let Some(pos) = text[from..].find(token) {
        let at = from + pos;
        let end = at + token.len();
        let pre_ok = !check_pre || at == 0 || !is_ident(bytes[at - 1]);
        let post_ok = !check_post || end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Mark every line that belongs to a `#[cfg(test)]`-gated block (the
/// attribute line itself, through the matching close brace).  Test code
/// may panic freely; the serving-path rules skip masked lines.
fn test_region_mask(blanked: &[String]) -> Vec<bool> {
    let mut mask = vec![false; blanked.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut floor: Option<i64> = None;
    for (idx, line) in blanked.iter().enumerate() {
        if floor.is_some() {
            mask[idx] = true;
        }
        if floor.is_none() && line.contains("#[cfg(") && has_token(line, "test") {
            pending = true;
            mask[idx] = true;
        }
        for c in line.chars() {
            if c == '{' {
                if pending && floor.is_none() {
                    floor = Some(depth);
                    pending = false;
                    mask[idx] = true;
                }
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                if floor.is_some_and(|f| depth <= f) {
                    floor = None;
                }
            }
        }
    }
    mask
}

/// Whether the raw line at `idx`, or the contiguous `//` comment block
/// directly above it, carries a `marker` comment.
fn excused(raw: &[&str], idx: usize, marker: &str) -> bool {
    if raw[idx].contains(marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains(marker) {
            return true;
        }
    }
    false
}

/// Inclusive line span (0-based) of the item whose header contains
/// `marker`, from the marker line through the close of its brace block.
fn item_span(blanked: &[String], marker: &str) -> Option<(usize, usize)> {
    let start = blanked.iter().position(|l| l.contains(marker))?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (idx, line) in blanked.iter().enumerate().skip(start) {
        for c in line.chars() {
            if c == '{' {
                depth += 1;
                opened = true;
            } else if c == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            return Some((start, idx));
        }
    }
    None
}

fn span_text(lines: &[&str], span: (usize, usize)) -> String {
    lines[span.0..=span.1].join("\n")
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel_path(root: &Path, p: &Path) -> PathBuf {
    p.strip_prefix(root).unwrap_or(p).to_path_buf()
}

// ---------------------------------------------------------------------------
// Check 1: opcode coverage (encoder, decoder, fuzz battery, README)

const PROTO: &str = "rust/src/net/proto.rs";
const CODEC_FUZZ: &str = "rust/tests/codec_fuzz.rs";
const README: &str = "rust/README.md";

/// `OP_LOOKUP_BULK` → `LookupBulk`.
fn camel(op_name: &str) -> String {
    let mut out = String::new();
    for word in op_name.trim_start_matches("OP_").split('_') {
        let mut cs = word.chars();
        if let Some(first) = cs.next() {
            out.push(first);
            out.push_str(&cs.as_str().to_ascii_lowercase());
        }
    }
    out
}

/// Parse `pub const <PREFIX>NAME: ty = literal;` declarations, returning
/// `(full name, literal text, 1-based line)`.
fn const_decls(blanked: &[String], prefix: &str) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for (idx, line) in blanked.iter().enumerate() {
        let Some(rest) = line.trim_start().strip_prefix("pub const ") else {
            continue;
        };
        if !rest.starts_with(prefix) {
            continue;
        }
        let Some((name, tail)) = rest.split_once(':') else {
            continue;
        };
        let Some((_, value)) = tail.split_once('=') else {
            continue;
        };
        let literal = value.trim().trim_end_matches(';').trim().to_string();
        out.push((name.trim().to_string(), literal, idx + 1));
    }
    out
}

fn check_opcodes(root: &Path, out: &mut Vec<Violation>) {
    let Some(proto) = read(root, PROTO, out) else {
        return;
    };
    let Some(fuzz) = read(root, CODEC_FUZZ, out) else {
        return;
    };
    let Some(readme) = read(root, README, out) else {
        return;
    };
    let proto_blanked = blank_noncode(&proto);
    let fuzz_blanked = blank_noncode(&fuzz).join("\n");

    let ops = const_decls(&proto_blanked, "OP_");
    if ops.is_empty() {
        out.push(Violation {
            file: PathBuf::from(PROTO),
            line: 0,
            rule: "opcode-coverage",
            msg: "no `pub const OP_*` opcode declarations found".into(),
        });
        return;
    }
    for (name, literal, line) in &ops {
        // Encoder arm `... => OP_NAME` vs decoder arm `OP_NAME => ...`:
        // the token's position relative to `=>` tells them apart.
        let mut encoder = false;
        let mut decoder = false;
        for l in &proto_blanked {
            let Some(arrow) = l.find("=>") else {
                continue;
            };
            if let Some(at) = token_pos(l, name) {
                if at > arrow {
                    encoder = true;
                } else {
                    decoder = true;
                }
            }
        }
        if !encoder {
            out.push(Violation {
                file: PathBuf::from(PROTO),
                line: *line,
                rule: "opcode-encoder",
                msg: format!("opcode {name} has no encoder match arm (`... => {name}`)"),
            });
        }
        if !decoder {
            out.push(Violation {
                file: PathBuf::from(PROTO),
                line: *line,
                rule: "opcode-decoder",
                msg: format!("opcode {name} has no decoder match arm (`{name} => ...`)"),
            });
        }
        let variant = camel(name);
        if !has_token(&fuzz_blanked, &format!("::{variant}")) {
            out.push(Violation {
                file: PathBuf::from(CODEC_FUZZ),
                line: 0,
                rule: "opcode-fuzz",
                msg: format!("fuzz battery never constructs `::{variant}` (opcode {name})"),
            });
        }
        let row = format!("{literal} {variant}");
        if !has_token(&readme, &row) {
            out.push(Violation {
                file: PathBuf::from(README),
                line: 0,
                rule: "opcode-readme",
                msg: format!("wire-op table is missing the `{row}` row (opcode {name})"),
            });
        }
    }

    // Every wire version up to the current one needs a history entry.
    let version = const_decls(&proto_blanked, "VERSION")
        .iter()
        .find(|(name, _, _)| name == "VERSION")
        .and_then(|(_, literal, _)| literal.parse::<u32>().ok());
    match version {
        Some(v) => {
            for k in 1..=v {
                let entry = format!("v{k} — ");
                if !readme.contains(&entry) {
                    out.push(Violation {
                        file: PathBuf::from(README),
                        line: 0,
                        rule: "wire-version",
                        msg: format!("version history is missing the `{entry}...` entry"),
                    });
                }
            }
        }
        None => out.push(Violation {
            file: PathBuf::from(PROTO),
            line: 0,
            rule: "wire-version",
            msg: "no parseable `pub const VERSION` declaration".into(),
        }),
    }
}

// ---------------------------------------------------------------------------
// Check 2: every EngineError variant maps to a wire error code, both ways

const ENGINE: &str = "rust/src/coordinator/engine.rs";

fn check_error_codes(root: &Path, out: &mut Vec<Violation>) {
    let Some(engine) = read(root, ENGINE, out) else {
        return;
    };
    let Some(proto) = read(root, PROTO, out) else {
        return;
    };
    let engine_blanked = blank_noncode(&engine);
    let proto_blanked = blank_noncode(&proto);

    let Some(enum_span) = item_span(&engine_blanked, "pub enum EngineError") else {
        out.push(Violation {
            file: PathBuf::from(ENGINE),
            line: 0,
            rule: "error-code-map",
            msg: "cannot locate `pub enum EngineError`".into(),
        });
        return;
    };
    // Variants are the capitalized identifiers opening lines at brace
    // depth 1 inside the enum body.
    let mut variants: Vec<(String, usize)> = Vec::new();
    let mut depth: i64 = 0;
    for idx in enum_span.0..=enum_span.1 {
        let line = &engine_blanked[idx];
        let trimmed = line.trim_start();
        if depth == 1 && trimmed.starts_with(|c: char| c.is_ascii_uppercase()) {
            let name: String =
                trimmed.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
            variants.push((name, idx + 1));
        }
        for c in line.chars() {
            if c == '{' {
                depth += 1;
            } else if c == '}' {
                depth -= 1;
            }
        }
    }
    if variants.is_empty() {
        out.push(Violation {
            file: PathBuf::from(ENGINE),
            line: enum_span.0 + 1,
            rule: "error-code-map",
            msg: "found no variants in `pub enum EngineError`".into(),
        });
        return;
    }

    let proto_lines: Vec<&str> = proto_blanked.iter().map(String::as_str).collect();
    for fn_marker in ["fn engine_error_code(", "fn engine_error_from_code("] {
        let Some(span) = item_span(&proto_blanked, fn_marker) else {
            out.push(Violation {
                file: PathBuf::from(PROTO),
                line: 0,
                rule: "error-code-map",
                msg: format!("cannot locate `{fn_marker}`"),
            });
            continue;
        };
        let body = span_text(&proto_lines, span);
        for (variant, line) in &variants {
            if !has_token(&body, &format!("EngineError::{variant}")) {
                out.push(Violation {
                    file: PathBuf::from(ENGINE),
                    line: *line,
                    rule: "error-code-map",
                    msg: format!("EngineError::{variant} is not handled by `{fn_marker}`"),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Check 3: no unexcused panics in serving-path modules

const SERVING_DIRS: [&str; 3] = ["rust/src/net", "rust/src/shard", "rust/src/store"];
const SERVING_FILES: [&str; 1] = ["rust/src/coordinator/server.rs"];

/// `.unwrap()` / `.expect(` calls and panicking macros; asserts are
/// deliberately allowed (they state invariants, not error handling).
const BANNED: [&str; 6] =
    [".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

fn check_panic_ban(root: &Path, out: &mut Vec<Violation>) {
    let mut files = Vec::new();
    for dir in SERVING_DIRS {
        walk_rs(&root.join(dir), &mut files);
    }
    for file in SERVING_FILES {
        let p = root.join(file);
        if p.is_file() {
            files.push(p);
        }
    }
    if files.is_empty() {
        out.push(Violation {
            file: PathBuf::from("rust/src"),
            line: 0,
            rule: "panic-ban",
            msg: "no serving-path sources found to scan".into(),
        });
        return;
    }
    for path in files {
        let Ok(source) = fs::read_to_string(&path) else {
            continue;
        };
        let blanked = blank_noncode(&source);
        let raw: Vec<&str> = source.split('\n').collect();
        let mask = test_region_mask(&blanked);
        for (idx, line) in blanked.iter().enumerate() {
            if mask[idx] {
                continue;
            }
            for banned in BANNED {
                if has_token(line, banned) && !excused(&raw, idx, "lint:allow(") {
                    out.push(Violation {
                        file: rel_path(root, &path),
                        line: idx + 1,
                        rule: "panic-ban",
                        msg: format!(
                            "`{banned}` in a serving path without a \
                             `// lint:allow(reason)` justification"
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Check 4: every Ordering::Relaxed carries a written rationale

fn check_relaxed(root: &Path, out: &mut Vec<Violation>) {
    let mut files = Vec::new();
    walk_rs(&root.join("rust/src"), &mut files);
    for path in files {
        let Ok(source) = fs::read_to_string(&path) else {
            continue;
        };
        let blanked = blank_noncode(&source);
        let raw: Vec<&str> = source.split('\n').collect();
        let mask = test_region_mask(&blanked);
        for (idx, line) in blanked.iter().enumerate() {
            if mask[idx] || !has_token(line, "Relaxed") {
                continue;
            }
            if !excused(&raw, idx, "lint:allow(relaxed") {
                out.push(Violation {
                    file: rel_path(root, &path),
                    line: idx + 1,
                    rule: "relaxed-ordering",
                    msg: "`Ordering::Relaxed` without a `// lint:allow(relaxed: reason)` \
                          rationale — justify it or upgrade the ordering"
                        .into(),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Check 5: config / manifest `key = value` codecs agree writer vs reader

const CONFIG: &str = "rust/src/config/mod.rs";
const STORE: &str = "rust/src/store/mod.rs";

/// Keys a kv writer emits: `key = ` line heads inside its string literals.
fn kv_writer_keys(body_raw: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for literal in string_literals(body_raw) {
        for line in literal.split('\n') {
            let end = line.find(|c: char| !(c.is_ascii_lowercase() || c == '_'));
            if let Some(end) = end {
                if end > 0 && line[end..].starts_with(" = ") {
                    keys.insert(line[..end].to_string());
                }
            }
        }
    }
    keys
}

/// Keys a kv reader accepts: quoted all-lowercase tokens on match-arm
/// (`=>`) lines inside its body.
fn kv_reader_keys(body_raw: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for line in body_raw.split('\n') {
        if !line.contains("=>") {
            continue;
        }
        for (i, piece) in line.split('"').enumerate() {
            if i % 2 == 1
                && !piece.is_empty()
                && piece.chars().all(|c| c.is_ascii_lowercase() || c == '_')
            {
                keys.insert(piece.to_string());
            }
        }
    }
    keys
}

/// Raw text of the item marked by `marker`, located via the blanked view.
fn raw_item(source: &str, marker: &str) -> Option<String> {
    let blanked = blank_noncode(source);
    let span = item_span(&blanked, marker)?;
    let raw: Vec<&str> = source.split('\n').collect();
    Some(span_text(&raw, span))
}

fn kv_fail(out: &mut Vec<Violation>, file: &str, msg: String) {
    out.push(Violation { file: PathBuf::from(file), line: 0, rule: "kv-keys", msg });
}

fn check_kv_keys(root: &Path, out: &mut Vec<Violation>) {
    let Some(config) = read(root, CONFIG, out) else {
        return;
    };
    let (Some(cfg_writer), Some(cfg_reader)) =
        (raw_item(&config, "pub fn to_kv("), raw_item(&config, "pub fn from_kv("))
    else {
        kv_fail(out, CONFIG, "cannot locate `pub fn to_kv` / `pub fn from_kv`".into());
        return;
    };
    let written = kv_writer_keys(&cfg_writer);
    let accepted = kv_reader_keys(&cfg_reader);
    if written.is_empty() {
        kv_fail(out, CONFIG, "config to_kv emits no recognizable `key = ` lines".into());
    }
    for key in written.difference(&accepted) {
        kv_fail(out, CONFIG, format!("to_kv writes `{key}` but from_kv has no arm for it"));
    }
    for key in accepted.difference(&written) {
        kv_fail(out, CONFIG, format!("from_kv accepts `{key}` but to_kv never writes it"));
    }

    let Some(store) = read(root, STORE, out) else {
        return;
    };
    let (Some(man_writer), Some(man_reader)) =
        (raw_item(&store, "pub fn to_kv("), raw_item(&store, "pub fn from_kv("))
    else {
        kv_fail(out, STORE, "cannot locate the manifest `to_kv` / `from_kv`".into());
        return;
    };
    let man_written = kv_writer_keys(&man_writer);
    let man_accepted = kv_reader_keys(&man_reader);
    for key in man_written.difference(&man_accepted) {
        kv_fail(out, STORE, format!("manifest to_kv writes `{key}` but from_kv has no arm for it"));
    }
    // The manifest embeds the config codec wholesale; its reader must
    // therefore accept every config key, and its writer must delegate.
    for key in written.difference(&man_accepted) {
        kv_fail(out, STORE, format!("manifest from_kv does not accept the config key `{key}`"));
    }
    if !man_writer.contains(".to_kv()") {
        kv_fail(out, STORE, "manifest to_kv no longer delegates to the config `.to_kv()`".into());
    }
}

// ---------------------------------------------------------------------------
// Check 6: bench-row JSON schema keys agree writer vs reader

const BENCH: &str = "rust/src/util/bench.rs";

fn check_bench_schema(root: &Path, out: &mut Vec<Violation>) {
    let Some(bench) = read(root, BENCH, out) else {
        return;
    };
    let mut fail = |msg: String| {
        out.push(Violation { file: PathBuf::from(BENCH), line: 0, rule: "bench-schema", msg });
    };
    let (Some(writer), Some(reader)) =
        (raw_item(&bench, "pub fn bench_rows_json("), raw_item(&bench, "pub fn read_bench_rows("))
    else {
        fail("cannot locate `bench_rows_json` / `read_bench_rows`".into());
        return;
    };
    let writer_literals = string_literals(&writer).join("\n");
    for key in ["schema", "rows", "name", "bench", "run"] {
        if !writer_literals.contains(&format!("\"{key}\"")) {
            fail(format!("bench_rows_json no longer emits the `\"{key}\"` field"));
        }
    }
    let reader_literals = string_literals(&reader);
    for key in ["rows", "name", "bench", "run"] {
        if !reader_literals.iter().any(|l| l == key) {
            fail(format!("read_bench_rows never reads the `\"{key}\"` field"));
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> Vec<Violation> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        assert!(root.is_dir(), "missing fixture tree {}", root.display());
        run(&root)
    }

    fn rules(violations: &[Violation]) -> Vec<&'static str> {
        violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn live_tree_upholds_every_invariant() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let violations = run(&root);
        let report: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
        assert!(violations.is_empty(), "live tree violations:\n{}", report.join("\n"));
    }

    #[test]
    fn rejects_an_opcode_without_a_decoder_arm() {
        let v = fixture("missing_decoder");
        assert!(rules(&v).contains(&"opcode-decoder"), "got: {:?}", rules(&v));
        assert!(!rules(&v).contains(&"opcode-encoder"), "encoder arms are all present");
    }

    #[test]
    fn rejects_an_opcode_missing_from_the_fuzz_battery() {
        let v = fixture("missing_fuzz_entry");
        assert!(rules(&v).contains(&"opcode-fuzz"), "got: {:?}", rules(&v));
    }

    #[test]
    fn rejects_readme_drift_in_op_table_and_version_history() {
        let v = fixture("missing_readme_row");
        assert!(rules(&v).contains(&"opcode-readme"), "got: {:?}", rules(&v));
        assert!(rules(&v).contains(&"wire-version"), "got: {:?}", rules(&v));
    }

    #[test]
    fn rejects_an_engine_error_variant_without_a_wire_code() {
        let v = fixture("unmapped_error_variant");
        let hits: Vec<&Violation> = v.iter().filter(|x| x.rule == "error-code-map").collect();
        // Busy is unmapped in both directions; Full is fine.
        assert_eq!(hits.len(), 2, "got: {:?}", rules(&v));
        assert!(hits.iter().all(|x| x.msg.contains("Busy")));
    }

    #[test]
    fn rejects_naked_panics_but_honors_allow_comments_and_test_code() {
        let v = fixture("naked_unwrap");
        let hits: Vec<&Violation> = v.iter().filter(|x| x.rule == "panic-ban").collect();
        assert_eq!(hits.len(), 1, "exactly the one naked unwrap: {:?}", rules(&v));
        assert_eq!(hits[0].line, 4, "points at the unwrap inside read_len");
    }

    #[test]
    fn rejects_an_unjustified_relaxed_ordering() {
        let v = fixture("unjustified_relaxed");
        let hits: Vec<&Violation> = v.iter().filter(|x| x.rule == "relaxed-ordering").collect();
        assert_eq!(hits.len(), 1, "exactly the one bare Relaxed: {:?}", rules(&v));
    }

    #[test]
    fn rejects_kv_key_drift_between_writer_and_reader() {
        let v = fixture("kv_key_drift");
        let hits: Vec<&Violation> = v.iter().filter(|x| x.rule == "kv-keys").collect();
        assert!(hits.iter().any(|x| x.msg.contains("`extra`")), "got: {:?}", rules(&v));
    }

    #[test]
    fn rejects_bench_schema_drift() {
        let v = fixture("bench_schema_drift");
        let hits: Vec<&Violation> = v.iter().filter(|x| x.rule == "bench-schema").collect();
        assert!(hits.iter().any(|x| x.msg.contains("run")), "got: {:?}", rules(&v));
    }

    #[test]
    fn lexer_blanks_strings_comments_and_char_literals() {
        let src = "let a = \"} panic! {\"; // panic! here\nlet b = '}';\nlet c = 1;";
        let lines = blank_noncode(src);
        assert_eq!(lines.len(), 3);
        assert!(!lines[0].contains("panic!"));
        assert!(!lines[0].contains('}'));
        assert!(!lines[1].contains('}'));
        assert_eq!(lines[2], "let c = 1;");
    }

    #[test]
    fn token_boundaries_reject_partial_identifier_matches() {
        assert!(has_token("OP_LOOKUP => x", "OP_LOOKUP"));
        assert!(!has_token("OP_LOOKUP_BULK => x", "OP_LOOKUP"));
        assert!(has_token("a.unwrap()", ".unwrap()"));
        assert!(!has_token("a.unwrap_or(0)", ".unwrap()"));
        assert!(has_token("fuzz(Request::Insert)", "::Insert"));
        assert!(!has_token("fuzz(Response::Inserted)", "::Insert"));
    }
}
