"""AOT path: artifacts lower to loadable HLO text and the lowered decode
executes (via jax on CPU) with the same semantics as the eager graph."""

import json
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import emit, lower_decode, lower_train, to_hlo_text
from compile.model import CnnConfig, decode, train


@pytest.fixture(scope="module")
def small_cfg():
    return CnnConfig(m=64, c=3, l=8, zeta=8)


def test_hlo_text_is_parseable_hlo(small_cfg):
    text = lower_decode(small_cfg, batch=4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True → tuple-typed root
    assert "(f32[4,8]" in text.replace(" ", "") or "f32[4,8]" in text


def test_train_hlo_lowered(small_cfg):
    text = lower_train(small_cfg, entries=small_cfg.m)
    assert "HloModule" in text
    assert f"f32[{small_cfg.cl},{small_cfg.m}]" in text


def test_emit_manifest_roundtrip(small_cfg):
    with tempfile.TemporaryDirectory() as d:
        manifest = emit(d, small_cfg, batches=[2])
        files = set(os.listdir(d))
        assert {"gd_decode_b2.hlo.txt", "train.hlo.txt", "add_entry.hlo.txt", "manifest.json"} <= files
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        assert on_disk["config"]["q"] == small_cfg.q
        dec = on_disk["artifacts"]["gd_decode_b2"]
        assert dec["outputs"][0]["shape"] == [2, small_cfg.beta]


def test_lowered_decode_matches_eager(small_cfg):
    """Compile the lowered module and compare against the eager graph —
    the strongest build-time check that what Rust will run is what we tested."""
    cfg = small_cfg
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, cfg.l, size=(4, cfg.c)), jnp.int32)
    entries_idx = jnp.asarray(rng.integers(0, cfg.l, size=(cfg.m, cfg.c)), jnp.int32)
    addr = jnp.arange(cfg.m, dtype=jnp.int32)
    w = train(entries_idx, addr, cfg)

    fn = lambda i, w_: decode(i, w_, cfg)
    compiled = jax.jit(fn).lower(idx, w).compile()
    en_c, lam_c = compiled(idx, w)
    en_e, lam_e = fn(idx, w)
    np.testing.assert_array_equal(np.asarray(en_c), np.asarray(en_e))
    np.testing.assert_array_equal(np.asarray(lam_c), np.asarray(lam_e))
