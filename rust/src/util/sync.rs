//! The concurrency kernel shared by the serving layers, extracted behind
//! one auditable facade: the lock-free MPMC batching channel the reader
//! pools and the network reactor drain, the RCU publish slot lookups
//! snapshot from, and the admission gauge that sheds load — plus the
//! poison-recovery lock helpers every serving path uses instead of
//! `.unwrap()` on a lock result.
//!
//! Two properties of this module are enforced elsewhere in the repo:
//!
//! * **loom-swappable primitives** — everything here builds against either
//!   `std::sync` (default) or `loom::sync` (cargo feature `loom`), so the
//!   model-checking battery in `rust/tests/loom_models.rs` can exhaustively
//!   interleave the channel/publish/drain protocols with the *same* code the
//!   production threads run, not a re-implementation that could drift.
//! * **no panic on poison** — a reader thread that panics while holding a
//!   stripe or parking lock must not wedge the whole bank: every lock/wait in
//!   this module recovers the guard with [`lock_recover`]/[`PoisonError::
//!   into_inner`].  The invariants the guards protect are documented at
//!   each recovery site; `cargo xtask lint` bans bare `.unwrap()`/`.expect`
//!   on lock results in the serving modules that build on this facade.

#[cfg(feature = "loom")]
pub use loom::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
#[cfg(feature = "loom")]
pub use loom::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
#[cfg(not(feature = "loom"))]
pub use std::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
#[cfg(not(feature = "loom"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use std::mem::MaybeUninit;
use std::sync::Arc;
use std::sync::PoisonError;

#[cfg(feature = "loom")]
use loom::thread::yield_now;
#[cfg(not(feature = "loom"))]
use std::thread::yield_now;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// Sound only when every critical section leaves the protected value in a
/// consistent state at every panic point — which is the standing rule for
/// this facade: critical sections are a few field updates (parking-lot
/// bookkeeping, counter bumps, metric folds) with no mid-section invariant
/// windows, so the data a poisoned guard hands back is never torn.
/// Recovering keeps one panicked reader from turning every later lock on
/// the bank into a panic cascade.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_recover`] for the read half of an [`RwLock`].
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_recover`] for the write half of an [`RwLock`].
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

// --------------------------------------------------------- publish slot

/// RCU-style publish slot: a single writer replaces the published
/// `Arc<T>`; any number of readers snapshot it and then work lock-free on
/// their clone.  The lock is held only for the pointer clone/store — never
/// across a search — so readers cannot block each other and the writer
/// blocks readers only for the O(1) swap.
///
/// This is the slot behind [`crate::coordinator::engine::SharedSearch`];
/// the loom battery checks that a snapshot never observes a torn or
/// rolled-back publication.
pub struct PublishSlot<T> {
    slot: RwLock<Arc<T>>,
}

impl<T> std::fmt::Debug for PublishSlot<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PublishSlot").finish_non_exhaustive()
    }
}

impl<T> PublishSlot<T> {
    pub fn new(initial: Arc<T>) -> Self {
        PublishSlot { slot: RwLock::new(initial) }
    }

    /// The currently published value (O(1): one read-lock + Arc clone).
    pub fn snapshot(&self) -> Arc<T> {
        read_recover(&self.slot).clone()
    }

    /// Publish `next`, making it the value every subsequent
    /// [`Self::snapshot`] returns.  In-flight snapshots keep their old
    /// `Arc` alive until dropped (that is the RCU grace period).
    pub fn publish(&self, next: Arc<T>) {
        *write_recover(&self.slot) = next;
    }
}

// ------------------------------------------------------ admission gauge

/// Count of lookup tags admitted (enqueued) but not yet picked up by a
/// serving thread — the load-shedding input for `try_lookup`'s `Busy`
/// path and the post-drain "queue is empty again" probe the tests read.
///
/// Orderings: [`Self::retire`] releases and [`Self::load`] acquires, so a
/// thread that observes the gauge at zero also observes the effects of
/// serving every retired job.  The drain barrier itself synchronizes
/// through the channel's completion counter, so the gauge does not carry
/// the barrier — the Acquire/Release pair is what makes the gauge's
/// *value* trustworthy on its own, without reasoning about which lock
/// happened to be held nearby (this replaced a set of `Ordering::Relaxed`
/// uses whose soundness rested on exactly that coupling).
pub struct AdmissionGauge {
    depth: AtomicUsize,
}

impl AdmissionGauge {
    pub fn new() -> Self {
        AdmissionGauge { depth: AtomicUsize::new(0) }
    }

    /// Count `n` tags into the queue (enqueue side).
    pub fn admit(&self, n: usize) {
        self.depth.fetch_add(n, Ordering::Release);
    }

    /// Count `n` tags out of the queue (serving side, or enqueue
    /// rollback when the send fails).  Admissions and retirements must
    /// balance; the debug assertion catches a weight mismatch (e.g. a
    /// bulk retired per-message instead of per-tag) in tests.
    pub fn retire(&self, n: usize) {
        let prev = self.depth.fetch_sub(n, Ordering::Release);
        debug_assert!(prev >= n, "admission gauge underflow: retired {n} from {prev}");
    }

    /// Current depth.
    pub fn load(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }
}

impl Default for AdmissionGauge {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------- MPMC batching channel

/// Per-slot cell.  Under loom this is loom's instrumented `UnsafeCell`
/// (so the model checker tracks the unsynchronized slot writes); the
/// default build is a zero-cost wrapper over `std::cell::UnsafeCell` with
/// the same closure-based access surface.
#[cfg(feature = "loom")]
use loom::cell::UnsafeCell as SlotCell;

#[cfg(not(feature = "loom"))]
struct SlotCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(feature = "loom"))]
impl<T> SlotCell<T> {
    fn new(v: T) -> Self {
        SlotCell(std::cell::UnsafeCell::new(v))
    }
    fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}

struct Slot<T> {
    /// Vyukov sequence number.  `seq == pos` means the slot is free for
    /// the producer claiming position `pos`; `seq == pos + 1` means the
    /// value for `pos` is published and a consumer may take it;
    /// `seq == pos + capacity` means the consumer is done and the slot is
    /// free for the producer claiming `pos + capacity`.
    seq: AtomicUsize,
    val: SlotCell<MaybeUninit<T>>,
}

/// A bounded lock-free MPMC channel with batched consumption and a
/// completion barrier — the serving-path replacement for the old
/// Mutex+Condvar `WorkQueue`.
///
/// * **Lock-free hot path.**  [`Self::try_push`] and the consume fast
///   path are a Vyukov array ring: producers claim a position with a CAS
///   on `tail`, write the slot, and publish with a release store on the
///   slot's sequence counter; consumers mirror it on `head`.  No mutex is
///   touched while the channel is non-empty.
/// * **Batched pop.**  [`Self::pop_batch`] drains up to `max` jobs in one
///   call so a reader-pool thread pays the synchronization cost once per
///   *batch*, not once per job.
/// * **Hybrid parking.**  Only an *empty* channel parks consumers, on a
///   Mutex+Condvar eventcount; producers take the lock only when a
///   consumer advertised it is asleep, so a busy channel never touches
///   the mutex.  The wakeup protocol (sleeper registration → SeqCst fence
///   → recheck, against publish → SeqCst fence → sleeper check) is
///   exhaustively interleaved by the loom battery.
/// * **`Busy` shedding stays upstream.**  [`Self::try_push`] hands the
///   job back when the ring is full; the admission layers above
///   ([`AdmissionGauge`] in the coordinator, reactor backpressure in
///   `net::server`) decide whether that becomes a typed `Busy` or a
///   stalled connection.  [`Self::push`] spins only for the transient
///   overshoot those layers permit.
///
/// Lifecycle matches the old queue: the channel starts with ONE sender
/// registered (the creator); [`Self::add_sender`]/[`Self::remove_sender`]
/// track clones.  Consumers block while senders remain and observe
/// end-of-stream only once every sender is gone *and* the ring ran dry —
/// queued jobs are always finished first.
pub struct BatchChannel<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Next position a producer will claim.
    tail: AtomicUsize,
    /// Next position a consumer will claim.
    head: AtomicUsize,
    /// Live sender handles; consumers exit once this hits zero and the
    /// ring is empty.
    senders: AtomicUsize,
    /// Jobs ever published (monotonic; drain-barrier bookkeeping).
    enqueued: AtomicUsize,
    /// Jobs fully served via [`Self::job_done`] (monotonic; a drain
    /// barrier waits for `completed` to reach the `enqueued` it observed).
    completed: AtomicUsize,
    /// Consumers currently inside the parking protocol.
    sleepers: AtomicUsize,
    /// Barrier callers currently parked on `drained`.
    barrier_waiters: AtomicUsize,
    /// Parking lot for empty-channel consumers (guards nothing; the
    /// condvar needs a mutex).
    park: Mutex<()>,
    takeable: Condvar,
    /// Parking lot for [`Self::barrier`] waiters.
    done: Mutex<()>,
    drained: Condvar,
}

// SAFETY: the ring hands each `T` from exactly one producer to exactly
// one consumer (the Vyukov sequence protocol makes slot claims exclusive
// and the publish/consume stores are Release/Acquire paired), so sharing
// the channel across threads only ever moves values between threads —
// `T: Send` is exactly the bound that makes that sound.
unsafe impl<T: Send> Send for BatchChannel<T> {}
// SAFETY: see the `Send` rationale — all shared mutable state is behind
// atomics or the slot protocol.
unsafe impl<T: Send> Sync for BatchChannel<T> {}

impl<T> BatchChannel<T> {
    /// A channel whose ring holds at least `capacity` jobs (rounded up to
    /// the next power of two, minimum 2).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: SlotCell::new(MaybeUninit::uninit()) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        BatchChannel {
            slots,
            mask: cap - 1,
            tail: AtomicUsize::new(0),
            head: AtomicUsize::new(0),
            senders: AtomicUsize::new(1),
            enqueued: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            barrier_waiters: AtomicUsize::new(0),
            park: Mutex::new(()),
            takeable: Condvar::new(),
            done: Mutex::new(()),
            drained: Condvar::new(),
        }
    }

    /// Ring capacity (always a power of two).
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Publish one job, or hand it back if the ring is full.  Lock-free;
    /// this is the reactor's shed/backpressure probe.
    pub fn try_push(&self, job: T) -> Result<(), T> {
        // lint:allow(relaxed: the CAS on `tail` only arbitrates which producer
        // owns a position; publication ordering is carried by the Release
        // store on the slot's `seq` below, and the Acquire load of `seq`
        // here orders this producer after the consumer that freed the slot)
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[tail & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - tail as isize;
            if dif == 0 {
                // lint:allow(relaxed: claim-only CAS, see rationale above —
                // the slot write is ordered by the seq Release publish)
                match self.tail.compare_exchange_weak(
                    tail,
                    tail.wrapping_add(1),
                    Ordering::Relaxed, // lint:allow(relaxed: claim-only, see above)
                    Ordering::Relaxed, // lint:allow(relaxed: failure re-reads tail)
                ) {
                    Ok(_) => {
                        slot.val.with_mut(|p| {
                            // SAFETY: the successful CAS on `tail` makes this
                            // producer the exclusive owner of the slot until
                            // the seq store below publishes it.
                            unsafe { (*p).write(job) };
                        });
                        slot.seq.store(tail.wrapping_add(1), Ordering::Release);
                        self.enqueued.fetch_add(1, Ordering::Release);
                        // Eventcount handshake: publish, fence, then check
                        // for sleepers.  Pairs with the register-fence-
                        // recheck sequence in `pop_batch`; the two SeqCst
                        // fences are totally ordered, so either this load
                        // sees the sleeper (and we wake it under the lock)
                        // or the sleeper's recheck sees our publish.
                        fence(Ordering::SeqCst);
                        // lint:allow(relaxed: ordered by the SeqCst fence
                        // directly above — see the eventcount comment)
                        if self.sleepers.load(Ordering::Relaxed) > 0 {
                            // Empty critical section: taking the parking
                            // lock orders this notify against a sleeper
                            // that registered but has not yet waited.
                            drop(lock_recover(&self.park));
                            self.takeable.notify_all();
                        }
                        return Ok(());
                    }
                    Err(t) => tail = t,
                }
            } else if dif < 0 {
                return Err(job); // full: the consumer lap has not freed this slot yet
            } else {
                // lint:allow(relaxed: re-read after losing the claim race;
                // same claim-only rationale as the load above)
                tail = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Publish one job, spinning while the ring is momentarily full.
    ///
    /// Callers bound ring occupancy externally (the coordinator admits at
    /// most its queue-capacity tags before pushing, and the ring is sized
    /// to that cap), so a full ring here is a transient overshoot from a
    /// racing admit — a brief yield loop, not a parking lot, is the right
    /// tool.
    pub fn push(&self, job: T) {
        let mut job = job;
        loop {
            match self.try_push(job) {
                Ok(()) => return,
                Err(back) => {
                    job = back;
                    yield_now();
                }
            }
        }
    }

    /// Take one published job if any is ready.  Lock-free.
    pub fn try_pop(&self) -> Option<T> {
        // lint:allow(relaxed: claim-only cursor load — the value read is
        // ordered by the Acquire load of the slot's `seq`, which pairs with
        // the producer's Release publish)
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[head & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (head.wrapping_add(1)) as isize;
            if dif == 0 {
                // lint:allow(relaxed: claim-only CAS on the consumer cursor;
                // the slot read is ordered by the seq Acquire above and the
                // free-for-reuse store below is Release)
                match self.head.compare_exchange_weak(
                    head,
                    head.wrapping_add(1),
                    Ordering::Relaxed, // lint:allow(relaxed: claim-only, see above)
                    Ordering::Relaxed, // lint:allow(relaxed: failure re-reads head)
                ) {
                    Ok(_) => {
                        let job = slot.val.with_mut(|p| {
                            // SAFETY: the successful CAS on `head` makes this
                            // consumer the exclusive owner of the published
                            // value; the producer wrote it before its seq
                            // Release, which our seq Acquire observed.
                            unsafe { (*p).assume_init_read() }
                        });
                        slot.seq.store(head.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(job);
                    }
                    Err(h) => head = h,
                }
            } else if dif < 0 {
                return None; // nothing published at this position yet
            } else {
                // lint:allow(relaxed: re-read after losing the claim race;
                // same claim-only rationale as the load above)
                head = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Drain up to `max` ready jobs into `out` without blocking; returns
    /// how many were taken.
    pub fn try_pop_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        let mut taken = 0;
        while taken < max {
            match self.try_pop() {
                Some(j) => {
                    out.push(j);
                    taken += 1;
                }
                None => break,
            }
        }
        taken
    }

    /// Blocking batch take: up to `max` jobs, at least one — unless every
    /// sender is gone and the ring ran dry, which returns 0 (worker
    /// shutdown).  The parking protocol is the eventcount described on
    /// the type.
    pub fn pop_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        loop {
            let n = self.try_pop_batch(max, out);
            if n > 0 {
                return n;
            }
            // Slow path: register as a sleeper, then recheck before
            // actually sleeping.  The guard is held across registration,
            // recheck and wait, so a producer that saw `sleepers > 0`
            // cannot complete its locked notify between our recheck and
            // our wait.
            let guard = lock_recover(&self.park);
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            fence(Ordering::SeqCst);
            let n = self.try_pop_batch(max, out);
            if n > 0 || self.senders.load(Ordering::SeqCst) == 0 {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                return n;
            }
            let guard = self.takeable.wait(guard).unwrap_or_else(PoisonError::into_inner);
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
        }
    }

    /// Blocking single take; `None` once every sender is gone and the
    /// ring ran dry (worker shutdown).
    pub fn pop(&self) -> Option<T> {
        let mut one = Vec::with_capacity(1);
        match self.pop_batch(1, &mut one) {
            0 => None,
            _ => one.pop(),
        }
    }

    /// Mark one popped job fully served (wakes barrier waiters).  Prefer
    /// [`JobGuard`], which calls this even if serving the job panics.
    pub fn job_done(&self) {
        self.completed.fetch_add(1, Ordering::Release);
        // Same eventcount handshake as the push/pop pair, against the
        // barrier's register-fence-recheck.
        fence(Ordering::SeqCst);
        // lint:allow(relaxed: ordered by the SeqCst fence directly above)
        if self.barrier_waiters.load(Ordering::Relaxed) > 0 {
            drop(lock_recover(&self.done));
            self.drained.notify_all();
        }
    }

    /// Drain *barrier*: block until every job published before this call
    /// has been served.  Deliberately NOT "wait until idle" — under a
    /// sustained stream from other senders the ring may never be empty,
    /// and a barrier must still complete in bounded time.
    pub fn barrier(&self) {
        let target = self.enqueued.load(Ordering::Acquire);
        if self.completed.load(Ordering::Acquire) >= target {
            return;
        }
        let mut guard = lock_recover(&self.done);
        self.barrier_waiters.fetch_add(1, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        while self.completed.load(Ordering::Acquire) < target {
            guard = self.drained.wait(guard).unwrap_or_else(PoisonError::into_inner);
        }
        self.barrier_waiters.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }

    /// Register one more sender (a handle clone).
    pub fn add_sender(&self) {
        self.senders.fetch_add(1, Ordering::SeqCst);
    }

    /// Unregister a sender; at zero, every parked consumer is woken so it
    /// can drain the ring and exit.
    pub fn remove_sender(&self) {
        if self.senders.fetch_sub(1, Ordering::SeqCst) == 1 {
            drop(lock_recover(&self.park));
            self.takeable.notify_all();
        }
    }
}

impl<T> Drop for BatchChannel<T> {
    fn drop(&mut self) {
        // Run destructors for any jobs still in the ring.
        while self.try_pop().is_some() {}
    }
}

impl<T> std::fmt::Debug for BatchChannel<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchChannel").field("capacity", &(self.mask + 1)).finish_non_exhaustive()
    }
}

/// Marks a dequeued job finished even if serving it panics — a job that
/// never counts as completed would wedge every later
/// [`BatchChannel::barrier`].
pub struct JobGuard<'a, T>(&'a BatchChannel<T>);

impl<'a, T> JobGuard<'a, T> {
    pub fn new(queue: &'a BatchChannel<T>) -> Self {
        JobGuard(queue)
    }
}

impl<T> Drop for JobGuard<'_, T> {
    fn drop(&mut self) {
        self.0.job_done();
    }
}

// Unit tests run against the std primitives (the loom battery is the
// schedule-exhaustive counterpart in rust/tests/loom_models.rs).
#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_hands_back_a_poisoned_guard() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rw_recover_hands_back_poisoned_guards() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*read_recover(&l), 1);
        *write_recover(&l) = 2;
        assert_eq!(*read_recover(&l), 2);
    }

    #[test]
    fn publish_slot_snapshots_the_latest_publication() {
        let slot = PublishSlot::new(Arc::new(1u32));
        let before = slot.snapshot();
        slot.publish(Arc::new(2));
        assert_eq!(*before, 1, "in-flight snapshots keep the old state alive");
        assert_eq!(*slot.snapshot(), 2);
    }

    #[test]
    fn admission_gauge_balances() {
        let g = AdmissionGauge::new();
        assert_eq!(g.load(), 0);
        g.admit(3);
        g.admit(1);
        assert_eq!(g.load(), 4);
        g.retire(3);
        g.retire(1);
        assert_eq!(g.load(), 0);
    }

    #[test]
    fn channel_serves_fifo_and_shuts_down() {
        let q = Arc::new(BatchChannel::with_capacity(8));
        q.push(1u32);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        q.job_done();
        assert_eq!(q.pop(), Some(2));
        q.job_done();
        q.remove_sender();
        assert_eq!(q.pop(), None, "no senders + empty ring = shutdown");
    }

    #[test]
    fn queued_jobs_are_served_before_shutdown() {
        let q = Arc::new(BatchChannel::with_capacity(8));
        q.push(1u32);
        q.remove_sender();
        assert_eq!(q.pop(), Some(1), "queued jobs outlive the last sender");
        q.job_done();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn try_push_hands_the_job_back_when_full() {
        let q: BatchChannel<u32> = BatchChannel::with_capacity(2);
        assert_eq!(q.capacity(), 2);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Ok(()));
        assert_eq!(q.try_push(3), Err(3), "a full ring sheds instead of blocking");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(3), Ok(()), "consuming frees the slot for reuse");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn pop_batch_drains_in_one_call() {
        let q: BatchChannel<u32> = BatchChannel::with_capacity(16);
        for i in 0..10 {
            q.push(i);
        }
        let mut out = Vec::new();
        assert_eq!(q.pop_batch(4, &mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.pop_batch(16, &mut out), 6, "a batch takes at most what is ready");
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn mpmc_delivers_every_job_exactly_once() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 4;
        const PER_PRODUCER: usize = 2_000;
        let q = Arc::new(BatchChannel::with_capacity(64));
        let consumers: Vec<_> = (0..CONSUMERS)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut batch = Vec::new();
                    loop {
                        batch.clear();
                        if q.pop_batch(32, &mut batch) == 0 {
                            break;
                        }
                        for &j in &batch {
                            q.job_done();
                            got.push(j);
                        }
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let q = Arc::clone(&q);
                q.add_sender();
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        q.push(p * PER_PRODUCER + i);
                        if i + 1 == PER_PRODUCER {
                            q.remove_sender();
                        }
                    }
                })
            })
            .collect();
        q.remove_sender(); // the creator's handle
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..PRODUCERS * PER_PRODUCER).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_per_producer_survives_contention() {
        let q = Arc::new(BatchChannel::with_capacity(8));
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..1_000u32 {
                    q.push(i);
                }
                q.remove_sender();
            })
        };
        let mut last = None;
        while let Some(v) = q.pop() {
            q.job_done();
            if let Some(prev) = last {
                assert!(v > prev, "single-producer stream reordered: {prev} then {v}");
            }
            last = Some(v);
        }
        producer.join().unwrap();
        assert_eq!(last, Some(999));
    }

    #[test]
    fn barrier_waits_for_prior_jobs_only() {
        let q = Arc::new(BatchChannel::with_capacity(8));
        q.push(10u32);
        q.push(11);
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                while let Some(_job) = q.pop() {
                    let _guard = JobGuard::new(&q);
                }
            })
        };
        q.barrier(); // must return once both queued jobs completed
        q.remove_sender();
        worker.join().unwrap();
        q.add_sender(); // barrier on an idle channel returns immediately
        q.barrier();
        q.remove_sender();
    }

    #[test]
    fn job_guard_completes_on_panic() {
        let q = Arc::new(BatchChannel::with_capacity(8));
        q.push(1u32);
        let q2 = Arc::clone(&q);
        let _ = std::thread::spawn(move || {
            let _job = q2.pop();
            let _guard = JobGuard::new(&q2);
            panic!("die mid-job");
        })
        .join();
        q.barrier(); // would hang forever if the panicked job never completed
    }

    #[test]
    fn drop_runs_destructors_for_undelivered_jobs() {
        let flag = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        struct Probe(Arc<std::sync::atomic::AtomicUsize>);
        impl Drop for Probe {
            fn drop(&mut self) {
                self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            }
        }
        let q = BatchChannel::with_capacity(4);
        q.push(Probe(Arc::clone(&flag)));
        q.push(Probe(Arc::clone(&flag)));
        drop(q);
        assert_eq!(flag.load(std::sync::atomic::Ordering::SeqCst), 2);
    }
}
