// Fixture: to_kv writes `extra`, from_kv has no arm for it.

pub struct DesignConfig {
    pub m: usize,
    pub n: usize,
    pub extra: usize,
}

impl DesignConfig {
    pub fn to_kv(&self) -> String {
        format!("# fixture config\nm = {}\nn = {}\nextra = {}\n", self.m, self.n, self.extra)
    }

    pub fn from_kv(text: &str) -> Option<DesignConfig> {
        let mut cfg = DesignConfig { m: 0, n: 0, extra: 0 };
        for line in text.lines() {
            let Some((key, value)) = line.split_once('=') else {
                continue;
            };
            match key.trim() {
                "m" => cfg.m = value.trim().parse().ok()?,
                "n" => cfg.n = value.trim().parse().ok()?,
                _ => {}
            }
        }
        Some(cfg)
    }
}
