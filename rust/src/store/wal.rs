//! The per-bank write-ahead log: an append-only file of Insert/Delete
//! records in length-prefixed, checksummed frames.
//!
//! File layout (all little-endian):
//!
//! ```text
//! [magic "CSWL"][version u16][reserved u16 = 0][generation u64]
//! [len u32][checksum u64][op u8][payload] ...        -- frames, appended
//! ```
//!
//! The **generation** ties the log to the snapshot that precedes it: a
//! compaction writes the snapshot stamped with generation `g+1`, then
//! resets the log to generation `g+1`.  If a crash lands between those
//! two steps, the reopened store sees a log whose generation is *older*
//! than the snapshot's and discards it wholesale — its records are
//! already inside the snapshot, and replaying them against it would
//! double-apply every insert (inflating the stale-delete counter and
//! potentially firing a spurious retrain, breaking bit-identical
//! recovery).  The reconciliation lives in
//! [`crate::store::BankStore::open`]; the log itself only records and
//! reports the number.
//!
//! `len` counts everything after itself (checksum + op + payload) and the
//! checksum is FNV-1a ([`crate::util::hash`], the same definition that
//! checksums wire frames) over the op byte and payload.  Appends are
//! *write-through*: every frame reaches the OS with a single `write(2)`
//! before the caller's mutation is acknowledged, so acknowledged records
//! survive a killed process unconditionally; surviving power loss
//! additionally needs an [`FsyncPolicy`] that syncs.
//!
//! **Torn-tail rule**: on open, frames are replayed in order until the
//! first invalid one (truncated mid-frame, bad length, bad checksum, or an
//! undecodable record).  Everything from that point on is discarded and
//! the file is truncated back to the last good frame — a crash mid-append
//! costs at most the unacknowledged tail, never the log.  The discarded
//! byte count is reported in [`WalRecovery`], so callers can distinguish
//! a clean open from a repaired one.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

use crate::bits::BitVec;
use crate::stats::Histogram;
use crate::store::StoreError;
use crate::util::codec::{put_bitvec, put_u64, Cursor};
use crate::util::hash::{fnv1a_bytes, Fnv1a};

/// WAL file magic.
pub const WAL_MAGIC: [u8; 4] = *b"CSWL";

/// On-disk WAL format version.  Compatibility rule: strict equality — a
/// reader refuses (typed [`StoreError::Incompatible`]) rather than guess
/// at an unknown layout.
pub const WAL_VERSION: u16 = 1;

/// Header bytes before the first frame (magic + version + reserved +
/// generation).
pub const WAL_HEADER_LEN: u64 = 16;

/// Upper bound on one WAL frame (1 MiB) — rejects garbage lengths before
/// any allocation; real records are a few dozen bytes (one tag plus an
/// address).
pub const MAX_WAL_FRAME_LEN: u32 = 1 << 20;

/// Record opcodes.
pub const WAL_OP_INSERT: u8 = 1;
pub const WAL_OP_DELETE: u8 = 2;

/// When the log syncs to the disk (not just to the OS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Never fsync: acknowledged records survive a killed *process* (the
    /// OS holds them) but not a power loss.  The default.
    Never,
    /// `fdatasync` after every append: full durability, slowest.
    Always,
    /// `fdatasync` every N appends: bounded loss window under power
    /// failure.  `EveryN(1)` behaves like [`FsyncPolicy::Always`].
    EveryN(usize),
}

/// One logged mutation.  `Insert` carries the address the engine chose so
/// replay is [`crate::coordinator::LookupEngine::insert_at`] — replacement
/// semantics and CNN training order reproduce exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    Insert { addr: u64, tag: BitVec },
    Delete { addr: u64 },
}

impl WalRecord {
    pub fn op(&self) -> u8 {
        match self {
            WalRecord::Insert { .. } => WAL_OP_INSERT,
            WalRecord::Delete { .. } => WAL_OP_DELETE,
        }
    }

    pub fn encode_payload(&self, buf: &mut Vec<u8>) {
        match self {
            WalRecord::Insert { addr, tag } => {
                put_u64(buf, *addr);
                put_bitvec(buf, tag);
            }
            WalRecord::Delete { addr } => put_u64(buf, *addr),
        }
    }

    /// Decode a record payload.  Total: corrupt input yields a typed
    /// [`StoreError::Corrupt`], never a panic (the codec fuzz battery
    /// hammers this path).
    pub fn decode(op: u8, payload: &[u8]) -> Result<WalRecord, StoreError> {
        let mut c = Cursor::new(payload);
        let rec = match op {
            WAL_OP_INSERT => WalRecord::Insert { addr: c.take_u64()?, tag: c.take_bitvec()? },
            WAL_OP_DELETE => WalRecord::Delete { addr: c.take_u64()? },
            other => return Err(StoreError::Corrupt(format!("unknown WAL op {other}"))),
        };
        c.finish()?;
        Ok(rec)
    }
}

/// Frame an already-encoded payload: `[len][checksum][op][payload]`.
fn frame_from(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut h = Fnv1a::new();
    h.update(&[op]);
    h.update(payload);
    let len = (8 + 1 + payload.len()) as u32;
    let mut out = Vec::with_capacity(4 + len as usize);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&h.finish().to_le_bytes());
    out.push(op);
    out.extend_from_slice(payload);
    out
}

/// Serialize one frame: `[len][checksum][op][payload]`.
pub fn encode_frame(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    rec.encode_payload(&mut payload);
    frame_from(rec.op(), &payload)
}

/// Borrowed-tag sibling of [`encode_frame`] for the insert hot path: the
/// serving thread logs every acknowledged insert, and cloning the tag just
/// to serialize it into a [`WalRecord`] and drop it would cost an
/// allocation per write.  Byte-identical to the owned encoding (asserted
/// in the tests, like the wire protocol's borrowed writers).
pub fn encode_insert_frame(addr: u64, tag: &BitVec) -> Vec<u8> {
    let mut payload = Vec::new();
    put_u64(&mut payload, addr);
    put_bitvec(&mut payload, tag);
    frame_from(WAL_OP_INSERT, &payload)
}

/// One parsing step over the raw frame region.
enum FrameStep {
    /// A whole valid frame: `consumed` bytes yielding `record`.
    Complete { consumed: usize, record: WalRecord },
    /// Clean end of the log.
    End,
    /// The torn/corrupt tail starts here (reason kept for the report).
    Torn(String),
}

fn parse_frame(buf: &[u8]) -> FrameStep {
    if buf.is_empty() {
        return FrameStep::End;
    }
    if buf.len() < 4 {
        return FrameStep::Torn("partial length prefix".into());
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len < 9 || len > MAX_WAL_FRAME_LEN {
        return FrameStep::Torn(format!("frame length {len} out of range"));
    }
    let len = len as usize;
    if buf.len() < 4 + len {
        return FrameStep::Torn(format!("frame needs {len} bytes, {} present", buf.len() - 4));
    }
    let body = &buf[4..4 + len];
    // lint:allow(infallible: the slice is exactly 8 bytes by construction,
    // and len >= 9 was checked above)
    let want = u64::from_le_bytes(<[u8; 8]>::try_from(&body[0..8]).expect("8 bytes"));
    let got = fnv1a_bytes(&body[8..]);
    if want != got {
        return FrameStep::Torn(format!(
            "frame checksum mismatch: header {want:#018x}, computed {got:#018x}"
        ));
    }
    match WalRecord::decode(body[8], &body[9..]) {
        Ok(record) => FrameStep::Complete { consumed: 4 + len, record },
        Err(e) => FrameStep::Torn(format!("undecodable record: {e}")),
    }
}

/// What one polling step over a (possibly live) WAL file produced — the
/// read half of log shipping ([`crate::repl`]).  A tailer holds a
/// `(generation, offset)` cursor; [`tail_wal`] answers with either the
/// whole frames past that cursor or the news that the log no longer
/// extends it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TailStep {
    /// Whole frames from the requested offset: `frames` holds the verbatim
    /// file bytes (re-checkable with [`decode_frames`] — every frame keeps
    /// its own length prefix and checksum), `next_offset` is the first byte
    /// past them (the subscriber's next cursor), and `remaining` counts the
    /// complete records beyond the byte cap (the subscriber's lag in
    /// records).  An empty batch with `remaining == 0` means the tailer is
    /// caught up.
    Batch { generation: u64, next_offset: u64, frames: Vec<u8>, records: u64, remaining: u64 },
    /// The log no longer extends the cursor: its generation changed (a
    /// compaction snapshotted and reset it) or the offset fell outside the
    /// frame region.  The subscriber must re-bootstrap from the snapshot
    /// stamped with `generation` instead of replaying a stale prefix —
    /// WAL replay is not idempotent, so resuming a stale cursor would
    /// double-apply records the snapshot already contains.
    Restarted { generation: u64 },
}

/// Read the whole frames past `(generation, offset)` from the log at
/// `path`, up to ~`max_bytes` of frame bytes per step (always at least one
/// complete frame when one is present, so a single frame larger than the
/// cap still makes progress).
///
/// Safe against a *live* writer: appends are write-through and frames are
/// length-prefixed + checksummed, so a concurrently appended partial frame
/// simply ends the batch (it will be complete by the next poll); a
/// concurrent reset is seen as a generation change and reported as
/// [`TailStep::Restarted`].  A header too short to validate (mid-reset) is
/// reported as `Restarted { generation: 0 }` — the subscriber re-fetches
/// the snapshot either way.  Wrong magic or an unknown version is refused
/// like [`Wal::open`] refuses it: that is a foreign file, not a race.
pub fn tail_wal(
    path: &Path,
    generation: u64,
    offset: u64,
    max_bytes: usize,
) -> Result<TailStep, StoreError> {
    let data = match std::fs::read(path) {
        Ok(d) => d,
        Err(e) => return Err(StoreError::Io(e)),
    };
    if data.len() < WAL_HEADER_LEN as usize {
        // mid-create or mid-reset: transient; re-bootstrap resolves it
        return Ok(TailStep::Restarted { generation: 0 });
    }
    if data[..4] != WAL_MAGIC {
        return Err(StoreError::Corrupt("bad magic in WAL header".into()));
    }
    let version = u16::from_le_bytes([data[4], data[5]]);
    if version != WAL_VERSION {
        return Err(StoreError::Incompatible(format!(
            "WAL format version {version}, this build reads {WAL_VERSION}"
        )));
    }
    if data[6] != 0 || data[7] != 0 {
        return Err(StoreError::Corrupt("nonzero reserved bytes in WAL header".into()));
    }
    let actual = u64::from_le_bytes([
        data[8], data[9], data[10], data[11], data[12], data[13], data[14], data[15],
    ]);
    if actual != generation || offset < WAL_HEADER_LEN || offset > data.len() as u64 {
        return Ok(TailStep::Restarted { generation: actual });
    }
    let start = offset as usize;
    let mut pos = start;
    let mut records = 0u64;
    let mut remaining = 0u64;
    let mut end = start;
    loop {
        match parse_frame(&data[pos..]) {
            FrameStep::Complete { consumed, .. } => {
                if pos == end && (records == 0 || pos - start + consumed <= max_bytes) {
                    records += 1;
                    end = pos + consumed;
                } else {
                    remaining += 1;
                }
                pos += consumed;
            }
            // a torn tail here is (usually) the writer mid-append: the
            // batch simply ends at the last complete frame
            FrameStep::End | FrameStep::Torn(_) => break,
        }
    }
    Ok(TailStep::Batch {
        generation,
        next_offset: end as u64,
        frames: data[start..end].to_vec(),
        records,
        remaining,
    })
}

/// Strictly decode a region of concatenated frames (a
/// [`TailStep::Batch`]'s `frames`, after it crossed a wire hop): every
/// frame must be complete and checksum-clean — a shipped batch has no
/// legitimate torn tail, so any defect is [`StoreError::Corrupt`].
pub fn decode_frames(buf: &[u8]) -> Result<Vec<WalRecord>, StoreError> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        match parse_frame(&buf[pos..]) {
            FrameStep::Complete { consumed, record } => {
                out.push(record);
                pos += consumed;
            }
            FrameStep::End => return Ok(out),
            FrameStep::Torn(reason) => {
                return Err(StoreError::Corrupt(format!("shipped frame region: {reason}")))
            }
        }
    }
}

/// What [`Wal::open`] found (and repaired) on disk.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WalRecovery {
    /// Complete records replayed.
    pub records: usize,
    /// Bytes discarded from the torn/corrupt tail (0 on a clean open).
    pub truncated_bytes: u64,
    /// Why the tail was discarded, when it was.
    pub torn_reason: Option<String>,
}

/// Cumulative append/fsync accounting for one log — the raw feed behind
/// the `cscam_wal_*` series of the `/metrics` exposition.  Counters and
/// latency histograms survive [`Wal::reset`] (they describe the handle's
/// lifetime, not one generation) and are absorbed into the bank's
/// [`crate::coordinator::Metrics`] on every metrics snapshot.
#[derive(Debug, Clone)]
pub struct WalStats {
    /// Frames appended (acknowledged `write(2)` calls).
    pub appends: u64,
    /// Frame bytes appended.
    pub appended_bytes: u64,
    /// `sync_data` calls issued (policy-driven and explicit).
    pub fsyncs: u64,
    /// Per-append `write(2)` wall time, nanoseconds.
    pub append_ns: Histogram,
    /// Per-fsync wall time, nanoseconds.
    pub fsync_ns: Histogram,
}

impl WalStats {
    pub fn new() -> Self {
        WalStats {
            appends: 0,
            appended_bytes: 0,
            fsyncs: 0,
            append_ns: Histogram::log_linear(1 << 30),
            fsync_ns: Histogram::log_linear(1 << 30),
        }
    }
}

impl Default for WalStats {
    fn default() -> Self {
        Self::new()
    }
}

/// The exact 16 header bytes for a given generation.
fn header_bytes(generation: u64) -> [u8; 16] {
    let mut h = [0u8; 16];
    h[..4].copy_from_slice(&WAL_MAGIC);
    h[4..6].copy_from_slice(&WAL_VERSION.to_le_bytes());
    h[8..16].copy_from_slice(&generation.to_le_bytes());
    h
}

/// An open, append-position WAL file.
///
/// The handle is always opened with `O_APPEND`, so every write lands at
/// the current end of file — in particular, appends issued *after* a
/// compaction's `set_len` go to the new, shorter end rather than the
/// stale pre-truncation offset (a plain write-mode cursor would leave a
/// zero-filled hole there and doom every later record at replay).
pub struct Wal {
    file: File,
    /// Current on-disk length (header + complete frames).
    len: u64,
    /// Snapshot lineage this log extends (see the module docs).
    generation: u64,
    policy: FsyncPolicy,
    appends_since_sync: usize,
    /// Set when a failed append could not be rolled back: the tail may
    /// hold a partial frame, so further appends would be silently
    /// unrecoverable and are refused instead.
    poisoned: bool,
    /// Cumulative append/fsync accounting (see [`WalStats`]).
    stats: WalStats,
}

impl Wal {
    /// Open (creating if absent), validate the header, replay every
    /// complete frame, and truncate the torn tail if there is one.
    /// Returns the log positioned for appending plus the replayed records.
    pub fn open(
        path: &Path,
        policy: FsyncPolicy,
    ) -> Result<(Wal, Vec<WalRecord>, WalRecovery), StoreError> {
        let data = match std::fs::read(path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(StoreError::Io(e)),
        };
        let mut recovery = WalRecovery::default();

        if data.len() < WAL_HEADER_LEN as usize {
            // Absent, empty, or torn mid-create/mid-reset.  A short file
            // is only repaired when its first bytes match the fixed part
            // of the header this build writes (magic, version, reserved —
            // the generation bytes may be any torn value); anything else
            // is some other file, and rewriting it would destroy data we
            // do not understand (the same refusal rule as a wrong magic).
            let fixed = header_bytes(0);
            let check = data.len().min(8);
            if !data.is_empty() && data[..check] != fixed[..check] {
                return Err(StoreError::Corrupt(
                    "file too short to be a WAL and not a torn header".into(),
                ));
            }
            recovery.truncated_bytes = data.len() as u64;
            if !data.is_empty() {
                recovery.torn_reason = Some("torn file header".into());
            }
            // A torn reset loses the generation; restarting at 0 is safe
            // because the snapshot reconciliation in BankStore::open
            // discards any log older than the snapshot's generation.
            {
                let mut f = OpenOptions::new()
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(path)?;
                f.write_all(&header_bytes(0))?;
                f.sync_data()?;
            }
            let file = OpenOptions::new().read(true).append(true).open(path)?;
            let wal = Wal {
                file,
                len: WAL_HEADER_LEN,
                generation: 0,
                policy,
                appends_since_sync: 0,
                poisoned: false,
                stats: WalStats::new(),
            };
            return Ok((wal, Vec::new(), recovery));
        }

        if data[..4] != WAL_MAGIC {
            // Wrong magic is NOT a torn tail: this is some other file, and
            // truncating it would destroy data we do not understand.
            return Err(StoreError::Corrupt("bad magic in WAL header".into()));
        }
        let version = u16::from_le_bytes([data[4], data[5]]);
        if version != WAL_VERSION {
            return Err(StoreError::Incompatible(format!(
                "WAL format version {version}, this build reads {WAL_VERSION}"
            )));
        }
        if data[6] != 0 || data[7] != 0 {
            return Err(StoreError::Corrupt("nonzero reserved bytes in WAL header".into()));
        }
        // lint:allow(infallible: 8-byte slice by construction, header length
        // was checked before entering this branch)
        let generation = u64::from_le_bytes(<[u8; 8]>::try_from(&data[8..16]).expect("8 bytes"));

        let mut records = Vec::new();
        let mut good = WAL_HEADER_LEN as usize;
        loop {
            match parse_frame(&data[good..]) {
                FrameStep::Complete { consumed, record } => {
                    records.push(record);
                    good += consumed;
                }
                FrameStep::End => break,
                FrameStep::Torn(reason) => {
                    recovery.truncated_bytes = (data.len() - good) as u64;
                    recovery.torn_reason = Some(reason);
                    break;
                }
            }
        }
        recovery.records = records.len();
        drop(data);

        let file = OpenOptions::new().read(true).append(true).open(path)?;
        if recovery.truncated_bytes > 0 {
            file.set_len(good as u64)?;
            file.sync_data()?;
        }
        let wal = Wal {
            file,
            len: good as u64,
            generation,
            policy,
            appends_since_sync: 0,
            poisoned: false,
            stats: WalStats::new(),
        };
        Ok((wal, records, recovery))
    }

    /// The generation recorded in the header (see the module docs).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Append one record.  Write-through: the frame reaches the OS before
    /// this returns; it additionally reaches the disk per the
    /// [`FsyncPolicy`].
    ///
    /// Failure safety: a failed `write` may have landed *part* of the
    /// frame (e.g. the disk filled mid-write).  That partial frame is cut
    /// back off with `set_len` so a later successful append cannot land
    /// beyond an undecodable hole — replay truncates at the first invalid
    /// frame, so any record past one would be silently lost despite a
    /// successful acknowledgement.  If even the rollback fails, the log is
    /// poisoned and every further append is refused until a compaction
    /// ([`Self::reset`]) re-establishes a clean tail.
    pub fn append(&mut self, rec: &WalRecord) -> Result<(), StoreError> {
        self.append_frame(&encode_frame(rec))
    }

    /// Log an insert without building an owned [`WalRecord`] (see
    /// [`encode_insert_frame`]); same contract as [`Self::append`].
    pub fn append_insert(&mut self, addr: u64, tag: &BitVec) -> Result<(), StoreError> {
        self.append_frame(&encode_insert_frame(addr, tag))
    }

    fn append_frame(&mut self, frame: &[u8]) -> Result<(), StoreError> {
        if self.poisoned {
            return Err(StoreError::Io(std::io::Error::other(
                "WAL poisoned by an earlier failed append; compact to recover",
            )));
        }
        let t0 = std::time::Instant::now();
        if let Err(e) = self.file.write_all(frame) {
            if self.file.set_len(self.len).is_err() {
                self.poisoned = true;
            }
            return Err(StoreError::Io(e));
        }
        self.len += frame.len() as u64;
        self.stats.appends += 1;
        self.stats.appended_bytes += frame.len() as u64;
        self.stats.append_ns.record(t0.elapsed().as_nanos() as u64);
        match self.policy {
            FsyncPolicy::Never => {}
            FsyncPolicy::Always => self.sync_timed()?,
            FsyncPolicy::EveryN(n) => {
                self.appends_since_sync += 1;
                if self.appends_since_sync >= n.max(1) {
                    self.sync_timed()?;
                    self.appends_since_sync = 0;
                }
            }
        }
        Ok(())
    }

    /// `sync_data` wrapped with the [`WalStats`] fsync counter and latency
    /// histogram — every policy-driven or explicit sync goes through here.
    fn sync_timed(&mut self) -> Result<(), StoreError> {
        let t0 = std::time::Instant::now();
        self.file.sync_data()?;
        self.stats.fsyncs += 1;
        self.stats.fsync_ns.record(t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    /// Force everything to the disk regardless of policy.
    pub fn sync(&mut self) -> Result<(), StoreError> {
        self.sync_timed()?;
        self.appends_since_sync = 0;
        Ok(())
    }

    /// Cumulative append/fsync accounting for this handle's lifetime.
    pub fn stats(&self) -> &WalStats {
        &self.stats
    }

    /// Refuse every further append until a successful [`Self::reset`].
    /// Used when the on-disk state has moved ahead of this log's
    /// generation — a snapshot landed but the subsequent reset failed, so
    /// any append accepted onto the old-generation log would be discarded
    /// wholesale at recovery despite its acknowledgement.
    pub fn poison(&mut self) {
        self.poisoned = true;
    }

    /// Drop every frame and stamp a new generation (after a snapshot
    /// carrying that generation made the frames redundant).  Also heals a
    /// log poisoned by a failed append — the suspect tail is gone along
    /// with everything else.  The whole file is rewritten: `set_len(0)`,
    /// then the header goes through the `O_APPEND` cursor at the new
    /// (zero) end of file.
    pub fn reset(&mut self, generation: u64) -> Result<(), StoreError> {
        self.file.set_len(0)?;
        self.file.write_all(&header_bytes(generation))?;
        self.file.sync_data()?;
        self.len = WAL_HEADER_LEN;
        self.generation = generation;
        self.appends_since_sync = 0;
        self.poisoned = false;
        Ok(())
    }

    /// Current file length (header + frames) — the compaction trigger.
    pub fn len_bytes(&self) -> u64 {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cscam-wal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert { addr: 0, tag: BitVec::from_u128(0xDEAD_BEEF, 32) },
            WalRecord::Insert { addr: 7, tag: BitVec::from_u128(0x1234, 70) },
            WalRecord::Delete { addr: 0 },
            WalRecord::Insert { addr: 0, tag: BitVec::from_u128(0xAB, 32) },
        ]
    }

    #[test]
    fn append_reopen_replays_in_order() {
        let path = tmp("roundtrip.wal");
        let recs = sample_records();
        {
            let (mut wal, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
            assert!(replayed.is_empty());
            assert_eq!(rec.truncated_bytes, 0);
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        let (wal, replayed, rec) = Wal::open(&path, FsyncPolicy::Always).unwrap();
        assert_eq!(replayed, recs);
        assert_eq!(rec.records, 4);
        assert_eq!(rec.truncated_bytes, 0);
        assert!(wal.len_bytes() > WAL_HEADER_LEN);
    }

    #[test]
    fn torn_tail_is_truncated_and_log_stays_usable() {
        let path = tmp("torn.wal");
        let recs = sample_records();
        {
            let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::EveryN(2)).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        // simulate a crash mid-append: half a frame of the next record
        let torn = encode_frame(&WalRecord::Delete { addr: 3 });
        let mut raw = std::fs::read(&path).unwrap();
        raw.extend_from_slice(&torn[..torn.len() / 2]);
        std::fs::write(&path, &raw).unwrap();

        let (mut wal, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(replayed, recs, "complete frames all survive");
        assert_eq!(rec.truncated_bytes as usize, torn.len() / 2);
        assert!(rec.torn_reason.is_some());
        // the truncated log accepts new appends and replays them
        wal.append(&WalRecord::Delete { addr: 7 }).unwrap();
        drop(wal);
        let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(replayed.len(), 5);
        assert_eq!(replayed[4], WalRecord::Delete { addr: 7 });
        assert_eq!(rec.truncated_bytes, 0);
    }

    #[test]
    fn corrupt_frame_starts_the_discarded_tail() {
        let path = tmp("corrupt.wal");
        let recs = sample_records();
        {
            let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
        }
        // flip one payload byte of the second frame: it and everything
        // after it are discarded (the tail rule is by offset, not count)
        let mut raw = std::fs::read(&path).unwrap();
        let hdr = WAL_HEADER_LEN as usize;
        let first = 4 + u32::from_le_bytes(raw[hdr..hdr + 4].try_into().unwrap()) as usize;
        let second_payload = hdr + first + 4 + 9; // header + frame1 + len + cksum+op
        raw[second_payload] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();

        let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(replayed, recs[..1], "only the frame before the corruption survives");
        assert!(rec.truncated_bytes > 0);
        assert!(rec.torn_reason.unwrap().contains("checksum"));
    }

    #[test]
    fn reset_clears_the_frame_region_and_stamps_the_generation() {
        let path = tmp("reset.wal");
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(wal.generation(), 0);
        for r in &sample_records() {
            wal.append(r).unwrap();
        }
        wal.reset(3).unwrap();
        assert_eq!(wal.len_bytes(), WAL_HEADER_LEN);
        assert_eq!(wal.generation(), 3);
        wal.append(&WalRecord::Delete { addr: 1 }).unwrap();
        drop(wal);
        let (wal, replayed, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(replayed, vec![WalRecord::Delete { addr: 1 }]);
        assert_eq!(wal.generation(), 3, "generation survives a reopen");
    }

    #[test]
    fn foreign_and_future_files_are_refused_not_truncated() {
        let path = tmp("foreign.wal");
        std::fs::write(&path, b"not a wal at all").unwrap();
        assert!(matches!(
            Wal::open(&path, FsyncPolicy::Never),
            Err(StoreError::Corrupt(_))
        ));
        let mut future = WAL_MAGIC.to_vec();
        future.extend_from_slice(&99u16.to_le_bytes());
        future.extend_from_slice(&[0, 0]);
        future.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &future).unwrap();
        assert!(matches!(
            Wal::open(&path, FsyncPolicy::Never),
            Err(StoreError::Incompatible(_))
        ));
        // short files are refused too, unless they are a prefix of OUR
        // header (a crash mid-create) — never rewrite a file we don't own
        std::fs::write(&path, b"junk!").unwrap();
        assert!(matches!(
            Wal::open(&path, FsyncPolicy::Never),
            Err(StoreError::Corrupt(_))
        ));
        std::fs::write(&path, &header_bytes(0)[..5]).unwrap();
        let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert!(replayed.is_empty());
        assert_eq!(rec.truncated_bytes, 5, "torn create is repaired");
        // a torn reset (fixed header complete, generation bytes partial)
        // is repaired to generation 0 — the snapshot reconciliation in
        // BankStore::open then discards the log if it predates a snapshot
        std::fs::write(&path, &header_bytes(7)[..12]).unwrap();
        let (wal, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert!(replayed.is_empty());
        assert_eq!(rec.truncated_bytes, 12);
        assert_eq!(wal.generation(), 0);
    }

    #[test]
    fn appends_after_compaction_land_at_the_new_end_on_a_fresh_log() {
        // Regression: the fresh-created handle must behave exactly like a
        // reopened one after set_len — every post-compaction append lands
        // at the truncated end, never at a stale pre-truncation offset.
        let path = tmp("fresh-compact.wal");
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        for r in &sample_records() {
            wal.append(r).unwrap();
        }
        let before = wal.len_bytes();
        wal.reset(1).unwrap();
        wal.append(&WalRecord::Delete { addr: 9 }).unwrap();
        assert!(wal.len_bytes() < before);
        drop(wal);
        let raw = std::fs::read(&path).unwrap();
        let hdr = WAL_HEADER_LEN as usize;
        assert!(!raw[hdr..].iter().all(|&b| b == 0), "no zero-filled hole after the header");
        let (_, replayed, rec) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        assert_eq!(replayed, vec![WalRecord::Delete { addr: 9 }]);
        assert_eq!(rec.truncated_bytes, 0);
    }

    #[test]
    fn stats_count_appends_bytes_and_policy_fsyncs() {
        let path = tmp("stats.wal");
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::EveryN(2)).unwrap();
        assert_eq!(wal.stats().appends, 0);
        let recs = sample_records();
        let mut bytes = 0u64;
        for r in &recs {
            bytes += encode_frame(r).len() as u64;
            wal.append(r).unwrap();
        }
        let s = wal.stats();
        assert_eq!(s.appends, 4);
        assert_eq!(s.appended_bytes, bytes);
        assert_eq!(s.fsyncs, 2, "EveryN(2) syncs on appends 2 and 4");
        assert_eq!(s.append_ns.total(), 4);
        assert_eq!(s.fsync_ns.total(), 2);
        // an explicit sync also counts, and the stats survive a reset
        wal.sync().unwrap();
        wal.reset(1).unwrap();
        assert_eq!(wal.stats().fsyncs, 3);
        assert_eq!(wal.stats().appends, 4, "reset keeps handle-lifetime stats");
    }

    #[test]
    fn borrowed_insert_encoding_matches_the_owned_one() {
        let tag = BitVec::from_u128(0xFEED_F00D, 70);
        let owned = encode_frame(&WalRecord::Insert { addr: 42, tag: tag.clone() });
        assert_eq!(owned, encode_insert_frame(42, &tag));
    }

    #[test]
    fn tail_follows_appends_and_caps_batches() {
        let path = tmp("tail.wal");
        let recs = sample_records();
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        for r in &recs {
            wal.append(r).unwrap();
        }
        // bootstrap cursor: generation 0, offset = first frame byte
        let step = tail_wal(&path, 0, WAL_HEADER_LEN, usize::MAX).unwrap();
        let TailStep::Batch { generation, next_offset, frames, records, remaining } = step else {
            panic!("caught-up log must answer a batch");
        };
        assert_eq!(generation, 0);
        assert_eq!(records, 4);
        assert_eq!(remaining, 0);
        assert_eq!(next_offset, wal.len_bytes());
        assert_eq!(decode_frames(&frames).unwrap(), recs);
        // caught up: an empty batch, same cursor
        let step = tail_wal(&path, 0, next_offset, usize::MAX).unwrap();
        assert_eq!(
            step,
            TailStep::Batch {
                generation: 0,
                next_offset,
                frames: Vec::new(),
                records: 0,
                remaining: 0
            }
        );
        // a 1-byte cap still ships one whole frame per step, and counts
        // the rest as lag
        let step = tail_wal(&path, 0, WAL_HEADER_LEN, 1).unwrap();
        let TailStep::Batch { records, remaining, frames, next_offset, .. } = step else {
            panic!("batch expected");
        };
        assert_eq!(records, 1);
        assert_eq!(remaining, 3);
        assert_eq!(decode_frames(&frames).unwrap(), recs[..1]);
        // chase the rest from the advanced cursor
        let step = tail_wal(&path, 0, next_offset, usize::MAX).unwrap();
        let TailStep::Batch { records, frames, .. } = step else { panic!("batch expected") };
        assert_eq!(records, 3);
        assert_eq!(decode_frames(&frames).unwrap(), recs[1..]);
    }

    #[test]
    fn tail_reports_a_restart_instead_of_a_stale_prefix() {
        let path = tmp("tail-restart.wal");
        let recs = sample_records();
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        for r in &recs {
            wal.append(r).unwrap();
        }
        let mid = tail_wal(&path, 0, WAL_HEADER_LEN, 64).unwrap();
        let TailStep::Batch { next_offset, .. } = mid else { panic!("batch expected") };
        // compaction resets the log: the old cursor must NOT replay bytes
        wal.reset(1).unwrap();
        wal.append(&WalRecord::Delete { addr: 5 }).unwrap();
        assert_eq!(
            tail_wal(&path, 0, next_offset, usize::MAX).unwrap(),
            TailStep::Restarted { generation: 1 },
            "stale generation must force a re-bootstrap"
        );
        // an out-of-range offset on the right generation is a restart too
        assert_eq!(
            tail_wal(&path, 1, wal.len_bytes() + 999, usize::MAX).unwrap(),
            TailStep::Restarted { generation: 1 }
        );
        assert_eq!(
            tail_wal(&path, 1, 3, usize::MAX).unwrap(),
            TailStep::Restarted { generation: 1 },
            "an offset inside the header is never a valid cursor"
        );
        // the fresh cursor reads the post-reset records
        let step = tail_wal(&path, 1, WAL_HEADER_LEN, usize::MAX).unwrap();
        let TailStep::Batch { records, frames, .. } = step else { panic!("batch expected") };
        assert_eq!(records, 1);
        assert_eq!(decode_frames(&frames).unwrap(), vec![WalRecord::Delete { addr: 5 }]);
    }

    #[test]
    fn tail_ends_batches_at_a_torn_tail_and_decode_frames_refuses_it() {
        let path = tmp("tail-torn.wal");
        let recs = sample_records();
        let (mut wal, _, _) = Wal::open(&path, FsyncPolicy::Never).unwrap();
        for r in &recs {
            wal.append(r).unwrap();
        }
        drop(wal);
        let torn = encode_frame(&WalRecord::Delete { addr: 3 });
        let mut raw = std::fs::read(&path).unwrap();
        raw.extend_from_slice(&torn[..torn.len() / 2]);
        std::fs::write(&path, &raw).unwrap();
        // a live tailer sees the complete frames and stops at the tear
        let step = tail_wal(&path, 0, WAL_HEADER_LEN, usize::MAX).unwrap();
        let TailStep::Batch { records, remaining, frames, .. } = step else {
            panic!("batch expected")
        };
        assert_eq!(records, 4);
        assert_eq!(remaining, 0);
        assert_eq!(decode_frames(&frames).unwrap(), recs);
        // but a *shipped* region with a tear is corrupt, never truncated
        let mut shipped = frames;
        shipped.extend_from_slice(&torn[..torn.len() / 2]);
        assert!(matches!(decode_frames(&shipped), Err(StoreError::Corrupt(_))));
        // foreign and future files are refused, not reported as restarts
        std::fs::write(&path, b"not a wal, definitely not").unwrap();
        assert!(matches!(
            tail_wal(&path, 0, WAL_HEADER_LEN, usize::MAX),
            Err(StoreError::Corrupt(_))
        ));
    }

    #[test]
    fn record_decode_is_total_on_garbage() {
        for op in 0..=3u8 {
            for len in 0..24usize {
                let payload: Vec<u8> = (0..len).map(|i| (i as u8).wrapping_mul(37)).collect();
                // must never panic; Ok only when the bytes happen to form a
                // complete record
                let _ = WalRecord::decode(op, &payload);
            }
        }
    }
}
