//! The TCP serving front-end: a thread-per-connection server that puts a
//! [`ShardedServerHandle`] fleet on the network.
//!
//! Shape: one nonblocking accept loop (so shutdown can interrupt it) that
//! spawns a handler thread per connection, each holding its own clone of
//! the fleet handle plus its own [`DecodeScratch`].  *Lookups run on the
//! connection thread itself* — the handler snapshots the owning bank's
//! published search state and searches directly
//! ([`ShardedServerHandle::lookup_direct`]), so a read never hops a
//! channel or waits behind another connection's work; only mutations and
//! barriers cross into the banks' writer threads:
//!
//! ```text
//!   client ──TCP──▶ conn thread ── lookups: SearchState snapshot (in place)
//!                   (BufReader/    ── mutations/barriers ──▶ bank writer
//!                    BufWriter,        threads (WAL, RCU publish —
//!                    frame decode,     crate::coordinator)
//!                    own scratch)
//! ```
//!
//! * a **connection cap**: past [`NetConfig::max_connections`] live
//!   connections, the server answers the handshake with the `busy` flag
//!   and closes (clients see [`crate::net::proto::WireError::Busy`]) —
//!   with direct reads this cap *is* the read-concurrency bound, giving
//!   natural backpressure instead of queue-shed (`ERR_BUSY` remains in
//!   the protocol for in-process admission surfaced over future paths);
//! * **clean shutdown**: a `Shutdown` request (or a local
//!   [`NetServerHandle::shutdown`]) stops the accept loop, waits briefly
//!   for live connections, then drains every bank before the serve thread
//!   exits.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{DecodeScratch, EngineError};
use crate::coordinator::server::PersistError;
use crate::net::proto::{
    self, parse_client_hello, write_server_hello, Request, Response, ServerHello, StatsReport,
    ERR_PROTOCOL, VERSION,
};
use crate::net::proto::WireError;
use crate::repl::ReplRole;
use crate::shard::ShardedServerHandle;

/// Tunables of the TCP front-end.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Live-connection cap; the accept loop answers `busy` past it.
    pub max_connections: usize,
    /// Poll granularity of the per-connection idle read (how fast a
    /// connection notices a shutdown).
    pub read_timeout: Duration,
    /// Poll granularity of the nonblocking accept loop.
    pub accept_poll: Duration,
    /// How long shutdown waits for live connections before draining anyway.
    pub shutdown_grace: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_connections: 64,
            read_timeout: Duration::from_millis(50),
            accept_poll: Duration::from_millis(5),
            shutdown_grace: Duration::from_secs(5),
        }
    }
}

/// A bound-but-not-yet-serving TCP front-end over a running fleet.
pub struct CamTcpServer {
    fleet: ShardedServerHandle,
    listener: TcpListener,
    cfg: NetConfig,
    repl: Option<Arc<ReplRole>>,
}

impl CamTcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) over a
    /// running fleet.
    pub fn bind(
        fleet: ShardedServerHandle,
        addr: impl ToSocketAddrs,
        cfg: NetConfig,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(CamTcpServer { fleet, listener, cfg, repl: None })
    }

    /// Give the front-end a replication role ([`crate::repl`]): a
    /// `Primary` answers `SubscribeLog` from its data directory and
    /// reports subscriber lag in its metrics; a `Replica` forwards
    /// `Insert`/`Delete` to its primary (reads stay local).  Taken as an
    /// `Arc` so the caller can share the same role with a metrics
    /// sidecar's render closure.
    pub fn with_repl(mut self, role: Arc<ReplRole>) -> Self {
        self.repl = Some(role);
        self
    }

    /// The bound address (read the ephemeral port from here).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Spawn the accept loop on its own thread.
    pub fn spawn(self) -> std::io::Result<NetServerHandle> {
        let addr = self.listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let fleet = self.fleet.clone();
        let thread = {
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("cscam-net-accept".into())
                .spawn(move || accept_loop(self.listener, self.fleet, self.cfg, self.repl, stop))?
        };
        Ok(NetServerHandle { addr, stop, thread: Some(thread), fleet })
    }
}

/// Handle to a serving TCP front-end.
pub struct NetServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    fleet: ShardedServerHandle,
}

impl NetServerHandle {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The fleet behind the server (local metrics / drains keep working).
    pub fn fleet(&self) -> &ShardedServerHandle {
        &self.fleet
    }

    /// Ask the accept loop to stop (idempotent; also triggered by a wire
    /// `Shutdown` request).  Banks are drained before the thread exits.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// True once shutdown has been requested (not necessarily completed).
    pub fn is_stopping(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Block until the serve thread has exited (call [`Self::shutdown`]
    /// first, or send a wire `Shutdown`).
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    fleet: ShardedServerHandle,
    cfg: NetConfig,
    repl: Option<Arc<ReplRole>>,
    stop: Arc<AtomicBool>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let live = Arc::new(AtomicUsize::new(0));
    let rejectors = Arc::new(AtomicUsize::new(0));
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                // the accepted socket must not inherit the listener's
                // nonblocking mode (platform-dependent)
                let _ = stream.set_nonblocking(false);
                if live.load(Ordering::Acquire) >= cfg.max_connections {
                    // Rejection waits up to 500 ms for the peer's hello —
                    // never on the accept thread (over-cap connectors would
                    // stall every legitimate accept behind them) and never
                    // on more than a few threads at once (a connect flood
                    // must not mint a thread per rejection; past the cap
                    // the stream just drops, which the peer sees as EOF).
                    if rejectors.load(Ordering::Acquire) < MAX_BUSY_REJECTORS {
                        let slot = LiveSlot::claim(&rejectors);
                        let hello = server_hello(&fleet, true);
                        let _ = std::thread::Builder::new()
                            .name("cscam-net-busy".into())
                            .spawn(move || {
                                let _slot = slot;
                                reject_busy(stream, hello);
                            });
                    }
                    continue;
                }
                // Slot guard: the slot frees even if serve_conn panics —
                // a leaked increment would wedge the server at `busy`.
                let slot = LiveSlot::claim(&live);
                let fleet = fleet.clone();
                let cfg = cfg.clone();
                let repl = repl.clone();
                let stop = Arc::clone(&stop);
                // spawn failure drops the unexecuted closure (and with it
                // the slot guard), so the count stays balanced either way
                let _ = std::thread::Builder::new()
                    .name("cscam-net-conn".into())
                    .spawn(move || {
                        let _slot = slot;
                        serve_conn(stream, &fleet, &cfg, repl.as_deref(), &stop);
                    });
            }
            // WouldBlock = no pending connection; other accept errors are
            // transient on a healthy listener — either way, poll again
            Err(_) => std::thread::sleep(cfg.accept_poll),
        }
    }
    // Clean shutdown: no new connections; give the live ones a grace
    // window, then run the canonical drain-then-flush sequence (no
    // acknowledged-but-unlogged writes).
    let deadline = Instant::now() + cfg.shutdown_grace;
    while live.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
        std::thread::sleep(cfg.accept_poll);
    }
    if let Err(e) = fleet.shutdown() {
        eprintln!("cscam-net: fleet shutdown flush failed: {e}");
    }
}

/// Concurrent polite-rejection bound: each busy hello may pin a thread for
/// up to 500 ms, so a connect flood gets at most this many courtesy
/// replies at a time — the rest are dropped outright.
const MAX_BUSY_REJECTORS: usize = 8;

/// RAII slot in a connection counter (live conns, busy rejectors):
/// claimed on the accept thread, released on drop — including a panicking
/// thread's unwind, so a crash can never wedge the server at `busy`.
struct LiveSlot(Arc<AtomicUsize>);

impl LiveSlot {
    fn claim(live: &Arc<AtomicUsize>) -> LiveSlot {
        live.fetch_add(1, Ordering::AcqRel);
        LiveSlot(Arc::clone(live))
    }
}

impl Drop for LiveSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

fn server_hello(fleet: &ShardedServerHandle, busy: bool) -> ServerHello {
    ServerHello {
        version: VERSION,
        busy,
        shards: fleet.shard_count() as u32,
        bank_m: fleet.bank_m() as u32,
        tag_bits: fleet.tag_bits() as u32,
    }
}

fn reject_busy(mut stream: TcpStream, hello: ServerHello) {
    // best-effort: read the client hello so the peer's write cannot race
    // the close, then answer busy
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut peer_hello = [0u8; 8];
    let _ = stream.read_exact(&mut peer_hello);
    let _ = write_server_hello(&mut stream, &hello);
    let _ = stream.flush();
}

/// How long a peer may stall without delivering a byte mid-buffer before
/// the connection is dropped.  Wall-clock, not retry-counted: the budget
/// must not scale with the socket's read timeout (the handshake uses a
/// 2 s timeout, the frame loop 50 ms — a retry *count* would let a
/// trickling handshake pin a connection slot for many minutes).
const STALL_BUDGET: Duration = Duration::from_secs(10);

/// Read exactly `buf.len()` bytes.  `Ok(false)` = idle timeout with zero
/// bytes consumed (only when `idle_ok`); a timeout *mid-buffer* keeps
/// waiting (a frame in flight is never abandoned half-read) until the
/// peer has delivered nothing for [`STALL_BUDGET`] — progress resets the
/// clock, so slow-but-alive peers survive and stalled ones cannot pin the
/// thread or its connection slot.
fn read_full(r: &mut impl Read, buf: &mut [u8], idle_ok: bool) -> std::io::Result<bool> {
    use std::io::ErrorKind;
    let mut filled = 0usize;
    let mut stall_deadline: Option<Instant> = None;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(ErrorKind::UnexpectedEof, "peer closed"));
            }
            Ok(n) => {
                filled += n;
                stall_deadline = None;
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if idle_ok && filled == 0 {
                    return Ok(false);
                }
                let now = Instant::now();
                let deadline = *stall_deadline.get_or_insert(now + STALL_BUDGET);
                if now >= deadline {
                    return Err(std::io::Error::new(
                        ErrorKind::TimedOut,
                        "peer stalled mid-frame",
                    ));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// One frame off a connection, tolerating idle timeouts between frames.
enum ConnRead {
    Idle,
    Closed,
    Frame(u64, Request),
    Corrupt(String),
}

fn read_conn_frame(r: &mut impl Read) -> ConnRead {
    let mut lenb = [0u8; 4];
    match read_full(r, &mut lenb, true) {
        Ok(false) => return ConnRead::Idle,
        Ok(true) => {}
        Err(_) => return ConnRead::Closed,
    }
    let len = match proto::check_frame_len(u32::from_le_bytes(lenb)) {
        Ok(l) => l,
        Err(e) => return ConnRead::Corrupt(e.to_string()),
    };
    let mut body = vec![0u8; len];
    if !matches!(read_full(r, &mut body, false), Ok(true)) {
        return ConnRead::Closed;
    }
    match proto::decode_frame_body(&body) {
        Ok((id, op, payload)) => match Request::decode(op, payload) {
            Ok(req) => ConnRead::Frame(id, req),
            Err(e) => ConnRead::Corrupt(e.to_string()),
        },
        Err(e) => ConnRead::Corrupt(e.to_string()),
    }
}

fn serve_conn(
    stream: TcpStream,
    fleet: &ShardedServerHandle,
    cfg: &NetConfig,
    repl: Option<&ReplRole>,
    stop: &Arc<AtomicBool>,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);

    // Handshake: one 2 s window for the 8-byte client hello; wrong magic
    // or version ends the connection before any state is touched.
    let _ = reader.get_ref().set_read_timeout(Some(Duration::from_secs(2)));
    let mut hello = [0u8; 8];
    if !matches!(read_full(&mut reader, &mut hello, true), Ok(true)) {
        return;
    }
    let peer_version = match parse_client_hello(&hello) {
        Ok(v) => v,
        Err(_) => return,
    };
    if write_server_hello(&mut writer, &server_hello(fleet, false)).is_err()
        || writer.flush().is_err()
    {
        return;
    }
    if peer_version != VERSION {
        return; // the client sees our version in the hello and gives up too
    }

    let _ = reader.get_ref().set_read_timeout(Some(cfg.read_timeout));
    // Per-connection decode scratch: lookups run on this thread, against
    // the banks' published snapshots, with zero shared mutable state.
    let mut scratch = DecodeScratch::new();
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        match read_conn_frame(&mut reader) {
            ConnRead::Idle => continue,
            ConnRead::Closed => return,
            ConnRead::Corrupt(msg) => {
                // a desynced stream cannot be trusted for framing anymore:
                // answer once (id 0), then hang up
                eprintln!("cscam-net: dropping connection: {msg}");
                let resp = Response::Error { code: ERR_PROTOCOL, aux: 0 };
                let _ = proto::write_response(&mut writer, 0, &resp);
                let _ = writer.flush();
                return;
            }
            ConnRead::Frame(id, req) => {
                let is_shutdown = matches!(req, Request::Shutdown);
                let resp = handle_request(fleet, req, &mut scratch, repl);
                let acked = matches!(resp, Response::ShutdownAck);
                if proto::write_response(&mut writer, id, &resp).is_err()
                    || writer.flush().is_err()
                {
                    return;
                }
                if is_shutdown && acked {
                    stop.store(true, Ordering::Release);
                    return;
                }
            }
        }
    }
}

/// Reject tags of the wrong width before they reach the router: the
/// engines answer a mismatch with a typed `TagWidth` error, but the
/// learned-prefix router reads fixed bit positions and would panic on a
/// too-narrow tag — a client mistake must never take down a conn thread.
fn check_width(fleet: &ShardedServerHandle, tag: &crate::bits::BitVec) -> Option<EngineError> {
    let want = fleet.tag_bits();
    (tag.len() != want).then(|| EngineError::TagWidth { got: tag.len(), want })
}

fn handle_request(
    fleet: &ShardedServerHandle,
    req: Request,
    scratch: &mut DecodeScratch,
    repl: Option<&ReplRole>,
) -> Response {
    match req {
        Request::Insert { tag } => {
            if let Some(e) = check_width(fleet, &tag) {
                return proto::error_response(&e);
            }
            // replica role: the mutation goes to the primary and comes
            // back through the log — never applied locally out of band
            if let Some(ReplRole::Replica(fw)) = repl {
                return match fw.insert(&tag) {
                    Ok(addr) => Response::Inserted { addr },
                    Err(e) => forward_error_response("insert", e),
                };
            }
            match fleet.insert(tag) {
                Ok(a) => Response::Inserted { addr: a as u64 },
                Err(e) => proto::error_response(&e),
            }
        }
        Request::Delete { addr } => {
            if let Some(ReplRole::Replica(fw)) = repl {
                return match fw.delete(addr) {
                    Ok(()) => Response::Deleted,
                    Err(e) => forward_error_response("delete", e),
                };
            }
            match fleet.delete(addr as usize) {
                Ok(()) => Response::Deleted,
                Err(e) => proto::error_response(&e),
            }
        }
        Request::Lookup { tag } => {
            // direct read: this thread snapshots the owning bank's state
            // and searches in place — no channel hop, no queue, identical
            // bits to the in-process path
            match fleet.lookup_direct(&tag, scratch) {
                Ok(o) => Response::Lookup(Box::new(o)),
                Err(e) => proto::error_response(&e),
            }
        }
        Request::LookupBulk { tags } => {
            // reject the whole frame on any bad width (a half-answered
            // frame would desync the client's per-item accounting)
            if let Some(e) = tags.iter().find_map(|t| check_width(fleet, t)) {
                return proto::error_response(&e);
            }
            Response::LookupBulk(fleet.lookup_many_direct(&tags, scratch))
        }
        Request::Stats => match stats_report(fleet) {
            Some(s) => Response::Stats(Box::new(s)),
            None => proto::error_response(&EngineError::Shutdown),
        },
        Request::Drain => {
            fleet.drain();
            Response::Drained
        }
        Request::Shutdown => {
            // the canonical drain-then-flush so the ack means "all accepted
            // work is done and durable"; the caller flips the stop flag
            // after writing the ack.  A failed flush must NOT ack — the
            // client would believe acked writes are on disk when they are
            // not — so it answers ERR_PERSIST and the server keeps serving
            // (the operator can retry or investigate).
            match fleet.shutdown() {
                Ok(_) => Response::ShutdownAck,
                Err(e) => persist_error_response("shutdown flush", e),
            }
        }
        Request::Snapshot => match fleet.snapshot_stores() {
            Ok(_) => Response::Snapshotted,
            Err(e) => persist_error_response("snapshot", e),
        },
        Request::Flush => match fleet.flush_stores() {
            Ok(_) => Response::Flushed,
            Err(e) => persist_error_response("flush", e),
        },
        Request::Metrics => match fleet.fleet_metrics() {
            // the wire op has no recovery report (that context lives with
            // the process that opened the data dir — the HTTP sidecar
            // renders it); everything else matches `GET /metrics`
            Some(fm) => {
                let repl_status = match repl {
                    Some(ReplRole::Primary(feed)) => Some(feed.status()),
                    _ => None,
                };
                Response::Metrics {
                    text: crate::obs::render_prometheus(
                        &fm,
                        fleet.bank_m(),
                        fleet.tag_bits(),
                        None,
                        repl_status.as_ref(),
                    ),
                }
            }
            None => proto::error_response(&EngineError::Shutdown),
        },
        Request::SubscribeLog { replica, epoch, bank, generation, offset } => match repl {
            Some(ReplRole::Primary(feed)) => feed.serve(replica, epoch, bank, generation, offset),
            // no feed here (in-memory fleet, or a replica — chaining is
            // not supported): the op is unknown to this server
            _ => Response::Error {
                code: proto::ERR_UNKNOWN_OP,
                aux: u64::from(proto::OP_SUBSCRIBE_LOG),
            },
        },
    }
}

/// Map a failed forwarded mutation onto the wire: typed engine errors
/// pass through untouched (the primary's verdict), admission shedding
/// stays `ERR_BUSY`, and a transport failure — the primary unreachable,
/// so the write was *not* accepted anywhere — answers `ERR_PERSIST` with
/// the detail in the server log.
fn forward_error_response(what: &str, e: WireError) -> Response {
    match e {
        WireError::Engine(e) => proto::error_response(&e),
        WireError::Busy => Response::Error { code: proto::ERR_BUSY, aux: 0 },
        other => {
            eprintln!("cscam-net: forwarded {what} failed: {other}");
            Response::Error { code: proto::ERR_PERSIST, aux: 0 }
        }
    }
}

/// Map a persistence failure onto the wire: a dead engine thread is the
/// usual `Shutdown`, a store failure is `ERR_PERSIST` (details stay in the
/// server log — the operator owns the disk, not the client).
fn persist_error_response(what: &str, e: PersistError) -> Response {
    match e {
        PersistError::Shutdown => proto::error_response(&EngineError::Shutdown),
        PersistError::Store(e) => {
            eprintln!("cscam-net: {what} failed: {e}");
            Response::Error { code: proto::ERR_PERSIST, aux: 0 }
        }
    }
}

fn stats_report(fleet: &ShardedServerHandle) -> Option<StatsReport> {
    let fm = fleet.fleet_metrics()?;
    Some(StatsReport {
        shards: fleet.shard_count() as u32,
        bank_m: fleet.bank_m() as u32,
        tag_bits: fleet.tag_bits() as u32,
        lookups: fm.aggregate.lookups,
        hits: fm.aggregate.hits,
        misses: fm.aggregate.misses,
        inserts: fm.aggregate.inserts,
        deletes: fm.aggregate.deletes,
        mean_lambda: fm.aggregate.lambda.mean(),
        mean_energy_fj: fm.aggregate.energy_fj.mean(),
        p50_ns: fm.aggregate.host_latency_ns.quantile(0.5),
        p99_ns: fm.aggregate.host_latency_ns.quantile(0.99),
        hottest_bank: fm.hottest_bank() as u32,
        hot_fraction: fm.hot_fraction(),
        per_bank_lookups: fm.per_bank.iter().map(|m| m.lookups).collect(),
    })
}
