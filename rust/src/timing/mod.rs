//! Delay model — logical-effort-flavoured, in FO4 units.
//!
//! Every path delay is expressed as a number of fanout-of-4 inverter delays
//! at the target node, then multiplied by the node's `fo4_ps`.  Structural
//! dependence on the geometry (M, N, l, ζ) is kept so that sweeps and
//! ablations respond; the FO4 coefficients are calibrated once against the
//! paper's three measured delays at 0.13 µm (Table II: Ref. NAND 2.30 ns,
//! Ref. NOR 0.55 ns, Proposed 0.70 ns) — the *proposed* anchor only pins the
//! CNN stage coefficient (SRAM word-line), not the ratio: the 30.4 % headline
//! still emerges from NAND's structural O(N) chain vs the wave-pipelined
//! NOR sub-block search.
//!
//! Paths modelled:
//!
//! * conventional NOR search: SL broadcast (buffer chain, log M) → 1-deep ML
//!   pull-down → sense amp → priority encoder (log M).
//! * conventional NAND search: same except the ML is an N-long series chain
//!   (delay ∝ N — segmented-Elmore, the dominant term).
//! * proposed (wave-pipelined, Fig. 4): stage 1 = CNN (one-hot decode → SRAM
//!   row read → c-input AND → ζ-group OR → enable drive), stage 2 = NOR
//!   search of one ζ-row sub-block.  The paper reports the *max reliable
//!   frequency*, i.e. the slower stage; latency is the stage sum.


pub mod wave;

use crate::cam::MatchlineKind;
use crate::config::DesignConfig;
use crate::tech::{self, TechNode};

/// FO4 coefficients of the delay model (dimensionless).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayConstants {
    /// SL broadcast buffer chain: a + b·log2(rows driven).
    pub sl_base: f64,
    pub sl_per_log_row: f64,
    /// NOR ML evaluate + sense.
    pub ml_nor_eval: f64,
    /// NAND ML chain delay per series bit.
    pub ml_nand_per_bit: f64,
    /// Priority encoder: a·log2(M).
    pub encoder_per_log: f64,
    /// One-hot decoder: a + b·log2(l).
    pub dec_base: f64,
    pub dec_per_log: f64,
    /// SRAM row read: a + b·log2(columns) (word-line RC dominates).
    pub sram_base: f64,
    pub sram_per_log_col: f64,
    /// P_II logic: c-input AND tree + ζ-group OR + enable driver.
    pub pii_per_log_c: f64,
    pub pii_or_per_log_zeta: f64,
    pub enable_drive: f64,
}

impl DelayConstants {
    /// Reference calibration (see module docs for the three anchors).
    pub const fn reference() -> Self {
        DelayConstants {
            sl_base: 1.0,
            sl_per_log_row: 0.30,
            ml_nor_eval: 3.0,
            ml_nand_per_bit: 0.295,
            encoder_per_log: 0.45,
            dec_base: 1.0,
            dec_per_log: 0.35,
            sram_base: 3.2,
            sram_per_log_col: 0.55,
            pii_per_log_c: 0.8,
            pii_or_per_log_zeta: 0.5,
            enable_drive: 1.2,
        }
    }
}

impl Default for DelayConstants {
    fn default() -> Self {
        Self::reference()
    }
}

fn log2f(x: usize) -> f64 {
    (x.max(1) as f64).log2().max(1.0)
}

/// Search delay of a conventional M×N CAM in FO4 units.
pub fn conventional_search_fo4(m: usize, n: usize, ml: MatchlineKind, k: &DelayConstants) -> f64 {
    let sl = k.sl_base + k.sl_per_log_row * log2f(m);
    let ml_d = match ml {
        MatchlineKind::Nor => k.ml_nor_eval,
        MatchlineKind::Nand => k.ml_nand_per_bit * n as f64,
    };
    let enc = k.encoder_per_log * log2f(m);
    sl + ml_d + enc
}

/// CNN classifier stage delay (Fig. 4 critical path) in FO4 units.
pub fn cnn_stage_fo4(cfg: &DesignConfig, k: &DelayConstants) -> f64 {
    let dec = k.dec_base + k.dec_per_log * log2f(cfg.l);
    let sram = k.sram_base + k.sram_per_log_col * log2f(cfg.m);
    let pii = k.pii_per_log_c * log2f(cfg.c.next_power_of_two())
        + k.pii_or_per_log_zeta * log2f(cfg.zeta);
    dec + sram + pii + k.enable_drive
}

/// Sub-block CAM search stage delay (ζ rows, N bits) in FO4 units.
pub fn subblock_stage_fo4(cfg: &DesignConfig, k: &DelayConstants) -> f64 {
    // Local SLs only span ζ rows, but the global broadcast still buffers
    // across the array height: keep the log M SL term plus one enable gate.
    let sl = k.sl_base + k.sl_per_log_row * log2f(cfg.m) + 0.5;
    let ml_d = match cfg.ml_kind {
        MatchlineKind::Nor => k.ml_nor_eval,
        MatchlineKind::Nand => k.ml_nand_per_bit * cfg.n as f64,
    };
    let enc = k.encoder_per_log * log2f(cfg.m);
    sl + ml_d + enc
}

/// Delay report for one architecture at a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayReport {
    /// Cycle time (max reliable frequency's period) in nanoseconds — what
    /// Table II reports.
    pub cycle_ns: f64,
    /// Input-to-output search latency in nanoseconds (= cycle for the
    /// single-stage conventional designs; stage sum for the wave-pipelined
    /// proposed design).
    pub latency_ns: f64,
}

/// Conventional design delay at `node`.
pub fn conventional_delay(
    m: usize,
    n: usize,
    ml: MatchlineKind,
    k: &DelayConstants,
    node: TechNode,
) -> DelayReport {
    let fo4 = conventional_search_fo4(m, n, ml, k);
    let ns = fo4 * node.fo4_ps / 1000.0;
    DelayReport { cycle_ns: ns, latency_ns: ns }
}

/// Proposed design delay at `node` (wave-pipelined two-stage path, §IV).
pub fn proposed_delay(cfg: &DesignConfig, k: &DelayConstants) -> DelayReport {
    let node = cfg.tech();
    let s1 = cnn_stage_fo4(cfg, k) * node.fo4_ps / 1000.0;
    let s2 = subblock_stage_fo4(cfg, k) * node.fo4_ps / 1000.0;
    DelayReport { cycle_ns: s1.max(s2), latency_ns: s1 + s2 }
}

/// Convenience: delays rescaled with the method of [6] instead of native
/// FO4 (used to sanity-check the scaling module against the delay model).
pub fn scaled_delay(report: DelayReport, from: TechNode, to: TechNode) -> DelayReport {
    DelayReport {
        cycle_ns: tech::scale_delay(report.cycle_ns, from, to),
        latency_ns: tech::scale_delay(report.latency_ns, from, to),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::NODE_130NM;

    fn k() -> DelayConstants {
        DelayConstants::reference()
    }

    #[test]
    fn ref_nor_delay_anchor() {
        // Table II: Ref. NOR 512×128 at 0.13 µm = 0.55 ns.
        let d = conventional_delay(512, 128, MatchlineKind::Nor, &k(), NODE_130NM);
        assert!((d.cycle_ns - 0.55).abs() < 0.05, "got {}", d.cycle_ns);
    }

    #[test]
    fn ref_nand_delay_anchor() {
        // Table II: Ref. NAND 512×128 at 0.13 µm = 2.30 ns.
        let d = conventional_delay(512, 128, MatchlineKind::Nand, &k(), NODE_130NM);
        assert!((d.cycle_ns - 2.30).abs() < 0.12, "got {}", d.cycle_ns);
    }

    #[test]
    fn proposed_delay_anchor_and_headline_ratio() {
        // Table II: Proposed = 0.70 ns; headline: 30.4 % of Ref. NAND.
        let cfg = DesignConfig::reference();
        let d = proposed_delay(&cfg, &k());
        assert!((d.cycle_ns - 0.70).abs() < 0.05, "got {}", d.cycle_ns);
        let nand = conventional_delay(512, 128, MatchlineKind::Nand, &k(), NODE_130NM);
        let ratio = d.cycle_ns / nand.cycle_ns;
        assert!((0.27..0.34).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cnn_stage_is_the_critical_stage_at_reference() {
        // §IV wave-pipelining: the CNN stage sets the cycle at the
        // reference point (0.70 > 0.55-ish sub-block search).
        let cfg = DesignConfig::reference();
        assert!(cnn_stage_fo4(&cfg, &k()) > subblock_stage_fo4(&cfg, &k()));
    }

    #[test]
    fn latency_is_stage_sum() {
        let cfg = DesignConfig::reference();
        let d = proposed_delay(&cfg, &k());
        assert!(d.latency_ns > d.cycle_ns);
        assert!(d.latency_ns < 2.0 * d.cycle_ns + 1e-9);
    }

    #[test]
    fn nand_delay_grows_linearly_with_tag_width() {
        let d64 = conventional_search_fo4(512, 64, MatchlineKind::Nand, &k());
        let d128 = conventional_search_fo4(512, 128, MatchlineKind::Nand, &k());
        let d256 = conventional_search_fo4(512, 256, MatchlineKind::Nand, &k());
        // the ML-chain term doubles with N: (d256−d128) = 2·(d128−d64)
        assert!(((d256 - d128) / (d128 - d64) - 2.0).abs() < 1e-9);
        assert!(d256 > d128 && d128 > d64);
    }

    #[test]
    fn nor_delay_insensitive_to_tag_width() {
        let d64 = conventional_search_fo4(512, 64, MatchlineKind::Nor, &k());
        let d256 = conventional_search_fo4(512, 256, MatchlineKind::Nor, &k());
        assert_eq!(d64, d256);
    }

    #[test]
    fn paper_90nm_projection_via_scaling() {
        // §IV: proposed 0.70 ns → 0.582 ns at 90 nm/1.0 V by the method of [6].
        let cfg = DesignConfig::reference();
        let d = proposed_delay(&cfg, &k());
        let s = scaled_delay(d, NODE_130NM, tech::NODE_90NM);
        assert!((s.cycle_ns - 0.582).abs() < 0.05, "got {}", s.cycle_ns);
    }
}
