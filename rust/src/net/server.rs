//! The TCP serving front-end: a readiness-driven event loop (reactor)
//! that puts a [`ShardedServerHandle`] fleet on the network.
//!
//! Shape: ONE reactor thread owns the listener and every connection —
//! all nonblocking, registered in a [`crate::net::poll::Poller`] (epoll
//! on Linux, `poll(2)` elsewhere).  Connections carry resumable codec
//! state machines: bytes accumulate in a per-connection read buffer and
//! frames are decoded only once complete, so a peer that delivers a
//! frame one byte at a time costs buffer space, not a blocked thread.
//! Decoded requests cross a bounded lock-free MPMC channel
//! ([`crate::util::sync::BatchChannel`]) to a small worker pool that
//! executes them — lookups against the banks' published RCU snapshots
//! ([`ShardedServerHandle::lookup_direct`]), mutations through the bank
//! writer threads — and completed responses come back to the reactor via
//! a completion list plus a doorbell, to be serialized into the
//! connection's bounded write buffer:
//!
//! ```text
//!   clients ──TCP──▶ reactor thread ──BatchChannel──▶ worker pool
//!            (epoll;  frame reassembly,               (handle_request:
//!             10k+    per-conn read/write              direct lookups on
//!             conns)  buffers, backpressure)           RCU snapshots,
//!                        ▲                             mutations → banks)
//!                        └──completions + doorbell──────┘
//! ```
//!
//! * **Multiplexing (protocol v6):** requests from one connection are
//!   executed concurrently by the pool and responses are written in
//!   *completion* order, re-matched by the client via request id — the
//!   server hello advertises [`crate::net::proto::ServerHello::multiplex`].
//! * **Backpressure, not unbounded memory:** past
//!   [`NetConfig::inflight_window`] outstanding requests or
//!   [`NetConfig::write_soft_cap`] unsent response bytes the reactor
//!   simply stops reading that connection (level-triggered readiness
//!   makes resuming free); a peer that never drains its responses is
//!   disconnected at [`NetConfig::write_hard_cap`].
//! * **Connection cap:** past [`NetConfig::max_connections`] live
//!   connections the *reactor itself* answers the handshake with the
//!   `busy` flag and closes (clients see
//!   [`crate::net::proto::WireError::Busy`]) — deterministic, with no
//!   thread spawn that could fail and silently drop the connection.
//! * **Clean shutdown:** a wire `Shutdown` (or a local
//!   [`NetServerHandle::shutdown`]) stops accepting, gives in-flight
//!   requests and unflushed responses a grace window, then drains every
//!   bank before the serve thread exits.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{DecodeScratch, EngineError};
use crate::coordinator::server::PersistError;
use crate::net::poll::{wake_pair, Interest, Poller, WakeHandle, WakeReader};
use crate::net::proto::{
    self, parse_client_hello, write_server_hello, Request, Response, ServerHello, StatsReport,
    ERR_PROTOCOL, VERSION,
};
use crate::net::proto::WireError;
use crate::repl::ReplRole;
use crate::shard::ShardedServerHandle;
use crate::util::sync::{lock_recover, BatchChannel, JobGuard, Mutex};

/// Tunables of the TCP front-end.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Live-connection cap; the reactor answers `busy` past it.
    pub max_connections: usize,
    /// Reactor tick: poll timeout, which bounds how fast the loop notices
    /// a local shutdown request or scans for stalled peers.
    pub accept_poll: Duration,
    /// How long shutdown waits for in-flight requests and unflushed
    /// responses before closing connections anyway.
    pub shutdown_grace: Duration,
    /// Request-executing worker threads behind the reactor (0 = one per
    /// available core, clamped to a small pool).
    pub workers: usize,
    /// How long a peer may stall without delivering a byte mid-frame (or
    /// mid-handshake) before the connection is dropped.  Progress resets
    /// the clock, so slow-but-alive peers survive and stalled ones cannot
    /// pin a connection slot.
    pub stall_budget: Duration,
    /// Most requests one connection may have in flight before the
    /// reactor stops reading it (multiplexing window).
    pub inflight_window: usize,
    /// Unsent response bytes at which the reactor stops reading the
    /// connection (backpressure threshold).
    pub write_soft_cap: usize,
    /// Unsent response bytes at which a peer that never drains is
    /// disconnected outright (hard memory bound per connection).
    pub write_hard_cap: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_connections: 64,
            accept_poll: Duration::from_millis(5),
            shutdown_grace: Duration::from_secs(5),
            workers: 0,
            stall_budget: Duration::from_secs(10),
            inflight_window: 256,
            write_soft_cap: 256 * 1024,
            write_hard_cap: 64 << 20,
        }
    }
}

impl NetConfig {
    fn worker_count(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map_or(4, |n| n.get()).clamp(2, 8)
    }
}

/// Handshake window for a connection that has sent nothing at all.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);
/// How long a goodbye (busy hello, version-mismatch hello, protocol-error
/// answer) may wait for its flush before the socket is closed anyway.
const GOODBYE_BUDGET: Duration = Duration::from_millis(500);
/// Over-cap connections currently being answered `busy`.  Past this a
/// connect flood is dropped outright (the peer sees EOF) — a courtesy
/// hello costs a slab slot for up to [`GOODBYE_BUDGET`], and the flood
/// must not grow that set without bound.
const MAX_BUSY_GOODBYES: usize = 64;
/// Ring capacity of the request channel between the reactor and the
/// worker pool; a full ring parks the decoded frame on its connection and
/// pauses reading it (backpressure), never drops it.
const JOB_RING_CAPACITY: usize = 4096;
/// Jobs a worker takes per channel round-trip.
const WORKER_BATCH: usize = 32;

/// A bound-but-not-yet-serving TCP front-end over a running fleet.
pub struct CamTcpServer {
    fleet: ShardedServerHandle,
    listener: TcpListener,
    cfg: NetConfig,
    repl: Option<Arc<ReplRole>>,
}

impl CamTcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) over a
    /// running fleet.
    pub fn bind(
        fleet: ShardedServerHandle,
        addr: impl ToSocketAddrs,
        cfg: NetConfig,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(CamTcpServer { fleet, listener, cfg, repl: None })
    }

    /// Give the front-end a replication role ([`crate::repl`]): a
    /// `Primary` answers `SubscribeLog` from its data directory and
    /// reports subscriber lag in its metrics; a `Replica` forwards
    /// `Insert`/`Delete` to its primary (reads stay local).  Taken as an
    /// `Arc` so the caller can share the same role with a metrics
    /// sidecar's render closure.
    pub fn with_repl(mut self, role: Arc<ReplRole>) -> Self {
        self.repl = Some(role);
        self
    }

    /// The bound address (read the ephemeral port from here).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Spawn the reactor and its worker pool.  Every thread the server
    /// will ever need is created here — a spawn failure surfaces as an
    /// error *now*, not as a connection silently dropped later.
    pub fn spawn(self) -> std::io::Result<NetServerHandle> {
        let addr = self.listener.local_addr()?;
        self.listener.set_nonblocking(true)?;
        let poller = Poller::new()?;
        let (wake, wake_rx) = wake_pair()?;
        poller.add(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
        poller.add(wake_rx.fd(), TOKEN_WAKE, Interest::READ)?;

        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(NetShared {
            jobs: BatchChannel::with_capacity(JOB_RING_CAPACITY),
            completions: Mutex::new(Vec::new()),
            wake,
        });

        let mut worker_handles = Vec::new();
        let spawn_workers = (|| -> std::io::Result<()> {
            for i in 0..self.cfg.worker_count() {
                let shared = Arc::clone(&shared);
                let fleet = self.fleet.clone();
                let repl = self.repl.clone();
                let stop = Arc::clone(&stop);
                worker_handles.push(
                    std::thread::Builder::new()
                        .name(format!("cscam-net-worker-{i}"))
                        .spawn(move || worker_loop(&shared, &fleet, repl.as_deref(), &stop))?,
                );
            }
            Ok(())
        })();

        let reactor = Reactor {
            poller,
            listener: Some(self.listener),
            wake_rx,
            slab: Vec::new(),
            free: Vec::new(),
            live: 0,
            busy_live: 0,
            any_parked: false,
            draining: false,
            last_stall_scan: Instant::now(),
            hello_serving: server_hello(&self.fleet, false),
            hello_busy: server_hello(&self.fleet, true),
            cfg: self.cfg,
            fleet: self.fleet.clone(),
            shared: Arc::clone(&shared),
            stop: Arc::clone(&stop),
        };

        let spawned = spawn_workers.and_then(|()| {
            std::thread::Builder::new()
                .name("cscam-net-reactor".into())
                .spawn(move || reactor.run(worker_handles))
        });
        match spawned {
            Ok(thread) => {
                Ok(NetServerHandle { addr, stop, thread: Some(thread), fleet: self.fleet })
            }
            Err(e) => {
                // unwind cleanly: release the channel so any workers that
                // did start exit instead of parking forever
                shared.jobs.remove_sender();
                Err(e)
            }
        }
    }
}

/// Handle to a serving TCP front-end.
pub struct NetServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    fleet: ShardedServerHandle,
}

impl NetServerHandle {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The fleet behind the server (local metrics / drains keep working).
    pub fn fleet(&self) -> &ShardedServerHandle {
        &self.fleet
    }

    /// Ask the reactor to stop (idempotent; also triggered by a wire
    /// `Shutdown` request).  Banks are drained before the thread exits.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// True once shutdown has been requested (not necessarily completed).
    pub fn is_stopping(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Block until the serve thread has exited (call [`Self::shutdown`]
    /// first, or send a wire `Shutdown`).
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ------------------------------------------------------------ job plumbing

/// One decoded request on its way to the worker pool.
struct NetJob {
    conn: u64,
    id: u64,
    req: Request,
}

/// One executed response on its way back to the reactor.
struct Completion {
    conn: u64,
    id: u64,
    resp: Response,
}

/// State shared between the reactor and its workers.
struct NetShared {
    jobs: BatchChannel<NetJob>,
    completions: Mutex<Vec<Completion>>,
    wake: WakeHandle,
}

fn worker_loop(
    shared: &NetShared,
    fleet: &ShardedServerHandle,
    repl: Option<&ReplRole>,
    stop: &AtomicBool,
) {
    let mut scratch = DecodeScratch::new();
    let mut batch: Vec<NetJob> = Vec::with_capacity(WORKER_BATCH);
    loop {
        batch.clear();
        if shared.jobs.pop_batch(WORKER_BATCH, &mut batch) == 0 {
            return; // channel closed and drained: reactor is gone
        }
        for job in batch.drain(..) {
            let _guard = JobGuard::new(&shared.jobs);
            let is_shutdown = matches!(job.req, Request::Shutdown);
            let resp = handle_request(fleet, job.req, &mut scratch, repl);
            if is_shutdown && matches!(resp, Response::ShutdownAck) {
                // flag first, then complete: the reactor that wakes for
                // this ack already sees the stop request
                stop.store(true, Ordering::Release);
            }
            lock_recover(&shared.completions).push(Completion {
                conn: job.conn,
                id: job.id,
                resp,
            });
            shared.wake.wake();
        }
    }
}

// ---------------------------------------------------------------- reactor

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKE: u64 = u64::MAX - 1;

fn token_of(idx: usize, gen: u32) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

fn split_token(token: u64) -> (usize, u32) {
    ((token & 0xFFFF_FFFF) as usize, (token >> 32) as u32)
}

/// Connection lifecycle.
enum Phase {
    /// Waiting for the 8-byte client hello.
    Handshake { deadline: Instant },
    /// Over the connection cap: wait for the peer's hello (so our close
    /// cannot clobber it with a reset), answer `busy`, then goodbye.
    BusyHello { deadline: Instant },
    /// Normal frame traffic.
    Serving,
    /// Flush what is queued (a hello or a protocol-error answer), discard
    /// any further input, then close.
    Goodbye { deadline: Instant },
}

struct Conn {
    stream: TcpStream,
    token: u64,
    phase: Phase,
    /// Read accumulator: `rbuf[rpos..]` is unparsed input (a partial
    /// frame survives here across readiness events — the resumable half
    /// of the codec state machine).
    rbuf: Vec<u8>,
    rpos: usize,
    /// Write accumulator: `wbuf[wpos..]` is serialized-but-unsent output.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Requests handed to the worker pool and not yet answered.
    inflight: usize,
    /// A decoded frame the full job ring refused; retried before any new
    /// parsing (per-connection order of *submission* is preserved).
    parked: Option<NetJob>,
    /// Armed while a partial frame (or handshake) is pending; progress
    /// re-arms it, expiry closes the connection.
    stall_deadline: Option<Instant>,
    /// Interest currently registered with the poller.
    interest: Interest,
    /// Counted against the busy-goodbye bound instead of the live cap.
    busy_reject: bool,
}

impl Conn {
    fn pending_out(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Should the reactor read more bytes from this peer right now?
    fn wants_read(&self, cfg: &NetConfig) -> bool {
        match self.phase {
            // goodbye still reads (and discards) so the peer's in-flight
            // bytes cannot turn our final answer into a TCP reset
            Phase::Goodbye { .. } => true,
            _ => {
                self.parked.is_none()
                    && self.inflight < cfg.inflight_window
                    && self.pending_out() < cfg.write_soft_cap
            }
        }
    }
}

enum Verdict {
    Alive,
    Dead,
}

struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

struct Reactor {
    poller: Poller,
    listener: Option<TcpListener>,
    wake_rx: WakeReader,
    slab: Vec<Slot>,
    free: Vec<usize>,
    live: usize,
    busy_live: usize,
    any_parked: bool,
    /// Shutdown drain mode: no new frames are parsed, input is discarded.
    draining: bool,
    last_stall_scan: Instant,
    hello_serving: ServerHello,
    hello_busy: ServerHello,
    cfg: NetConfig,
    fleet: ShardedServerHandle,
    shared: Arc<NetShared>,
    stop: Arc<AtomicBool>,
}

impl Reactor {
    fn run(mut self, workers: Vec<std::thread::JoinHandle<()>>) {
        let mut events = Vec::new();
        while !self.stop.load(Ordering::Acquire) {
            self.process_completions();
            if self.any_parked {
                self.retry_parked();
            }
            events.clear();
            if self.poller.wait(&mut events, Some(self.cfg.accept_poll)).is_err() {
                break; // a dead poller cannot serve; fall through to drain
            }
            for ev in &events {
                self.handle_event(*ev);
            }
            self.maybe_scan_stalls();
        }
        self.shutdown_sequence(workers);
    }

    fn handle_event(&mut self, ev: crate::net::poll::Event) {
        match ev.token {
            TOKEN_WAKE => {
                self.wake_rx.drain();
                self.process_completions();
            }
            TOKEN_LISTENER => self.accept_ready(),
            token => self.conn_event(token, ev),
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = self.listener.as_ref() else { return };
            match listener.accept() {
                Ok((stream, _peer)) => self.register_conn(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                // transient accept errors on a healthy listener: the next
                // readiness event retries
                Err(_) => return,
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream) {
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let busy = self.live >= self.cfg.max_connections;
        if busy && self.busy_live >= MAX_BUSY_GOODBYES {
            return; // flood control: drop outright, the peer sees EOF
        }
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slab.push(Slot { gen: 0, conn: None });
                self.slab.len() - 1
            }
        };
        let token = token_of(idx, self.slab[idx].gen);
        let now = Instant::now();
        let phase = if busy {
            Phase::BusyHello { deadline: now + GOODBYE_BUDGET }
        } else {
            Phase::Handshake { deadline: now + HANDSHAKE_TIMEOUT }
        };
        let conn = Conn {
            stream,
            token,
            phase,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            inflight: 0,
            parked: None,
            stall_deadline: None,
            interest: Interest::READ,
            busy_reject: busy,
        };
        if self.poller.add(conn.stream.as_raw_fd(), token, Interest::READ).is_err() {
            self.free.push(idx);
            return; // conn drops here; the peer sees EOF
        }
        self.slab[idx].conn = Some(conn);
        if busy {
            self.busy_live += 1;
        } else {
            self.live += 1;
        }
    }

    fn conn_event(&mut self, token: u64, ev: crate::net::poll::Event) {
        let (idx, gen) = split_token(token);
        let mut dead = false;
        {
            let Some(slot) = self.slab.get_mut(idx) else { return };
            if slot.gen != gen {
                return; // stale event for a recycled slot
            }
            let Some(c) = slot.conn.as_mut() else { return };
            if ev.writable && flush_wbuf(c).is_err() {
                dead = true;
            }
            if !dead && ev.readable {
                dead = matches!(
                    handle_readable(
                        c,
                        &self.cfg,
                        &self.shared,
                        &mut self.any_parked,
                        self.draining,
                        &self.hello_serving,
                        &self.hello_busy,
                    ),
                    Verdict::Dead
                );
            } else if !dead && ev.writable {
                // The flush may have dropped `pending_out` back under the
                // soft cap.  Frames that were paused *after* being pulled
                // into `rbuf` get no further readiness events, so resume
                // the parser here (it never touches the socket).
                dead = matches!(
                    drive_conn(
                        c,
                        &self.cfg,
                        &self.shared,
                        &mut self.any_parked,
                        self.draining,
                        &self.hello_serving,
                        &self.hello_busy,
                    ),
                    Verdict::Dead
                );
            }
            if !dead {
                dead = matches!(settle_conn(&self.poller, c, &self.cfg), Verdict::Dead);
            }
        }
        if dead {
            self.close_idx(idx);
        }
    }

    /// Move every completed response into its connection's write buffer.
    fn process_completions(&mut self) {
        let done = std::mem::take(&mut *lock_recover(&self.shared.completions));
        if done.is_empty() {
            return;
        }
        let mut to_close = Vec::new();
        for comp in done {
            let (idx, gen) = split_token(comp.conn);
            let Some(slot) = self.slab.get_mut(idx) else { continue };
            if slot.gen != gen {
                continue; // the connection died before its answer was ready
            }
            let Some(c) = slot.conn.as_mut() else { continue };
            c.inflight = c.inflight.saturating_sub(1);
            let mut dead = proto::write_response(&mut c.wbuf, comp.id, &comp.resp).is_err();
            if !dead {
                // Flush before resuming the parser so the soft-cap check
                // sees what the kernel could not take, not the transient
                // spike from the response appended above.
                dead = flush_wbuf(c).is_err();
            }
            if !dead {
                // The freed window slot (and the flush above) may unblock
                // frames already sitting in this connection's read buffer;
                // no further readiness event will arrive for those bytes,
                // so resume the parser here.
                dead = matches!(
                    drive_conn(
                        c,
                        &self.cfg,
                        &self.shared,
                        &mut self.any_parked,
                        self.draining,
                        &self.hello_serving,
                        &self.hello_busy,
                    ),
                    Verdict::Dead
                );
            }
            if !dead {
                dead = matches!(settle_conn(&self.poller, c, &self.cfg), Verdict::Dead);
            }
            if dead {
                to_close.push(idx);
            }
        }
        for idx in to_close {
            self.close_idx(idx);
        }
    }

    /// Re-offer parked jobs to the ring (space appears as workers drain
    /// it), then resume parsing the frames queued up behind them.
    fn retry_parked(&mut self) {
        self.any_parked = false;
        let mut to_close = Vec::new();
        for idx in 0..self.slab.len() {
            let Some(c) = self.slab[idx].conn.as_mut() else { continue };
            let Some(job) = c.parked.take() else { continue };
            match self.shared.jobs.try_push(job) {
                Ok(()) => {
                    c.inflight += 1;
                    let mut dead = matches!(
                        drive_conn(
                            c,
                            &self.cfg,
                            &self.shared,
                            &mut self.any_parked,
                            self.draining,
                            &self.hello_serving,
                            &self.hello_busy,
                        ),
                        Verdict::Dead
                    );
                    if !dead {
                        dead = matches!(settle_conn(&self.poller, c, &self.cfg), Verdict::Dead);
                    }
                    if dead {
                        to_close.push(idx);
                    }
                }
                Err(job) => {
                    c.parked = Some(job);
                    self.any_parked = true;
                }
            }
        }
        for idx in to_close {
            self.close_idx(idx);
        }
    }

    /// Periodic sweep for peers that stalled mid-frame, handshakes that
    /// never arrived, and goodbyes whose flush window expired.
    fn maybe_scan_stalls(&mut self) {
        let now = Instant::now();
        if now.duration_since(self.last_stall_scan) < Duration::from_millis(100) {
            return;
        }
        self.last_stall_scan = now;
        let mut to_close = Vec::new();
        for (idx, slot) in self.slab.iter().enumerate() {
            let Some(c) = slot.conn.as_ref() else { continue };
            let expired = match c.phase {
                Phase::Handshake { deadline } | Phase::BusyHello { deadline } => {
                    now >= c.stall_deadline.unwrap_or(deadline)
                }
                Phase::Goodbye { deadline } => now >= deadline,
                Phase::Serving => c.stall_deadline.is_some_and(|d| now >= d),
            };
            if expired {
                to_close.push(idx);
            }
        }
        for idx in to_close {
            self.close_idx(idx);
        }
    }

    fn close_idx(&mut self, idx: usize) {
        let Some(slot) = self.slab.get_mut(idx) else { return };
        let Some(c) = slot.conn.take() else { return };
        let _ = self.poller.remove(c.stream.as_raw_fd());
        if c.busy_reject {
            self.busy_live -= 1;
        } else {
            self.live -= 1;
        }
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(idx);
        // dropping `c` closes the socket
    }

    fn all_quiet(&self) -> bool {
        self.slab.iter().all(|s| match &s.conn {
            None => true,
            Some(c) => c.inflight == 0 && c.parked.is_none() && c.pending_out() == 0,
        })
    }

    /// Clean shutdown: stop accepting immediately, give in-flight work
    /// and unflushed responses a grace window, then run the canonical
    /// drain-then-flush sequence (no acknowledged-but-unlogged writes).
    fn shutdown_sequence(mut self, workers: Vec<std::thread::JoinHandle<()>>) {
        if let Some(l) = self.listener.take() {
            let _ = self.poller.remove(l.as_raw_fd());
            drop(l); // the port refuses new connections from here on
        }
        self.draining = true;
        let deadline = Instant::now() + self.cfg.shutdown_grace;
        let mut events = Vec::new();
        loop {
            self.process_completions();
            if self.any_parked {
                self.retry_parked();
            }
            if self.all_quiet() || Instant::now() >= deadline {
                break;
            }
            events.clear();
            if self.poller.wait(&mut events, Some(self.cfg.accept_poll)).is_err() {
                break;
            }
            for ev in &events {
                self.handle_event(*ev);
            }
        }
        for idx in 0..self.slab.len() {
            self.close_idx(idx);
        }
        // Release the channel: workers finish the backlog, observe
        // end-of-stream, and exit; their final completions have nowhere
        // to go, which is fine — every connection is gone.
        self.shared.jobs.remove_sender();
        for w in workers {
            let _ = w.join();
        }
        if let Err(e) = self.fleet.shutdown() {
            eprintln!("cscam-net: fleet shutdown flush failed: {e}");
        }
    }
}

// -------------------------------------------------- per-connection engine

/// Pull bytes off the socket while the connection wants them, advancing
/// the codec state machine after every chunk.
fn handle_readable(
    c: &mut Conn,
    cfg: &NetConfig,
    shared: &NetShared,
    any_parked: &mut bool,
    draining: bool,
    hello_serving: &ServerHello,
    hello_busy: &ServerHello,
) -> Verdict {
    let mut buf = [0u8; 16 * 1024];
    // Bounded rounds per readiness event: level-triggered polling re-fires
    // for the remainder, so one firehose connection cannot starve the rest.
    for _ in 0..8 {
        if !c.wants_read(cfg) {
            return Verdict::Alive;
        }
        match c.stream.read(&mut buf) {
            Ok(0) => return Verdict::Dead,
            Ok(n) => {
                if matches!(c.phase, Phase::Goodbye { .. }) || draining {
                    // goodbye/drain: the bytes are dead — swallow them so
                    // the peer's writes cannot reset our final answer
                } else {
                    c.rbuf.extend_from_slice(&buf[..n]);
                }
                if let Verdict::Dead =
                    drive_conn(c, cfg, shared, any_parked, draining, hello_serving, hello_busy)
                {
                    return Verdict::Dead;
                }
                if n < buf.len() {
                    return Verdict::Alive;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Verdict::Alive,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Verdict::Dead,
        }
    }
    Verdict::Alive
}

/// Advance the connection's state machine over whatever `rbuf` holds:
/// complete the handshake, decode complete frames into jobs, arm/clear
/// the stall clock.  Never touches the socket.
fn drive_conn(
    c: &mut Conn,
    cfg: &NetConfig,
    shared: &NetShared,
    any_parked: &mut bool,
    draining: bool,
    hello_serving: &ServerHello,
    hello_busy: &ServerHello,
) -> Verdict {
    let now = Instant::now();
    loop {
        match c.phase {
            Phase::Goodbye { .. } => {
                c.rbuf.clear();
                c.rpos = 0;
                return Verdict::Alive;
            }
            Phase::BusyHello { .. } => {
                if c.rbuf.len() - c.rpos < 8 {
                    if c.rbuf.len() > c.rpos {
                        c.stall_deadline = Some(now + cfg.stall_budget);
                    }
                    return Verdict::Alive;
                }
                c.rpos += 8; // the peer's hello, politely consumed
                let _ = write_server_hello(&mut c.wbuf, hello_busy);
                c.phase = Phase::Goodbye { deadline: now + GOODBYE_BUDGET };
                c.stall_deadline = None;
            }
            Phase::Handshake { .. } => {
                if c.rbuf.len() - c.rpos < 8 {
                    if c.rbuf.len() > c.rpos {
                        c.stall_deadline = Some(now + cfg.stall_budget);
                    }
                    return Verdict::Alive;
                }
                let mut hello = [0u8; 8];
                hello.copy_from_slice(&c.rbuf[c.rpos..c.rpos + 8]);
                c.rpos += 8;
                c.stall_deadline = None;
                let peer_version = match parse_client_hello(&hello) {
                    Ok(v) => v,
                    // wrong magic: not our protocol, end it without a word
                    Err(_) => return Verdict::Dead,
                };
                let _ = write_server_hello(&mut c.wbuf, hello_serving);
                if peer_version != VERSION {
                    // the client sees our version in the hello and gives
                    // up too; flush it, then goodbye
                    c.phase = Phase::Goodbye { deadline: now + GOODBYE_BUDGET };
                } else {
                    c.phase = Phase::Serving;
                }
            }
            Phase::Serving => {
                if draining {
                    c.rbuf.clear();
                    c.rpos = 0;
                    return Verdict::Alive;
                }
                match parse_frames(c, cfg, shared, any_parked) {
                    Ok(()) => {
                        compact_rbuf(c);
                        // a partial frame left behind arms the stall clock
                        // (unless *we* paused the connection — then the
                        // peer owes us nothing)
                        if c.rbuf.len() > c.rpos && c.parked.is_none() && c.wants_read(cfg) {
                            c.stall_deadline = Some(now + cfg.stall_budget);
                        } else {
                            c.stall_deadline = None;
                        }
                        return Verdict::Alive;
                    }
                    Err(msg) => {
                        // a desynced stream cannot be trusted for framing
                        // anymore: answer once (id 0), then hang up
                        eprintln!("cscam-net: dropping connection: {msg}");
                        let resp = Response::Error { code: ERR_PROTOCOL, aux: 0 };
                        let _ = proto::write_response(&mut c.wbuf, 0, &resp);
                        c.rbuf.clear();
                        c.rpos = 0;
                        c.stall_deadline = None;
                        c.phase = Phase::Goodbye { deadline: now + GOODBYE_BUDGET };
                    }
                }
            }
        }
    }
}

/// Decode every complete frame in `rbuf` into a worker job, respecting
/// the multiplexing window and the write-buffer backpressure thresholds.
/// `Err` carries the protocol-corruption message.
fn parse_frames(
    c: &mut Conn,
    cfg: &NetConfig,
    shared: &NetShared,
    any_parked: &mut bool,
) -> Result<(), String> {
    loop {
        if c.parked.is_some()
            || c.inflight >= cfg.inflight_window
            || c.pending_out() >= cfg.write_soft_cap
        {
            return Ok(());
        }
        let avail = c.rbuf.len() - c.rpos;
        if avail < 4 {
            return Ok(());
        }
        let len_bytes =
            [c.rbuf[c.rpos], c.rbuf[c.rpos + 1], c.rbuf[c.rpos + 2], c.rbuf[c.rpos + 3]];
        let len = match proto::check_frame_len(u32::from_le_bytes(len_bytes)) {
            Ok(l) => l,
            Err(e) => return Err(e.to_string()),
        };
        if avail < 4 + len {
            return Ok(()); // resumable: the tail arrives on a later event
        }
        let frame_end = c.rpos + 4 + len;
        let (id, req) = {
            let body = &c.rbuf[c.rpos + 4..frame_end];
            match proto::decode_frame_body(body) {
                Ok((id, op, payload)) => match Request::decode(op, payload) {
                    Ok(r) => (id, r),
                    Err(e) => return Err(e.to_string()),
                },
                Err(e) => return Err(e.to_string()),
            }
        };
        c.rpos = frame_end;
        match shared.jobs.try_push(NetJob { conn: c.token, id, req }) {
            Ok(()) => c.inflight += 1,
            Err(job) => {
                // ring full: park the frame and pause this connection
                // until workers free a slot — backpressure, not loss
                c.parked = Some(job);
                *any_parked = true;
                return Ok(());
            }
        }
    }
}

fn compact_rbuf(c: &mut Conn) {
    if c.rpos == c.rbuf.len() {
        c.rbuf.clear();
        c.rpos = 0;
    } else if c.rpos > 16 * 1024 {
        c.rbuf.drain(..c.rpos);
        c.rpos = 0;
    }
}

/// Write as much of `wbuf` to the socket as it will take right now.
fn flush_wbuf(c: &mut Conn) -> std::io::Result<()> {
    while c.wpos < c.wbuf.len() {
        match c.stream.write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "peer took no bytes",
                ))
            }
            Ok(n) => c.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    if c.wpos == c.wbuf.len() {
        c.wbuf.clear();
        c.wpos = 0;
    } else if c.wpos > 64 * 1024 {
        c.wbuf.drain(..c.wpos);
        c.wpos = 0;
    }
    Ok(())
}

/// Post-step bookkeeping shared by every path that may have changed a
/// connection's buffers: flush, enforce the hard write bound, finish a
/// goodbye whose answer got out, and re-register poller interest.
fn settle_conn(poller: &Poller, c: &mut Conn, cfg: &NetConfig) -> Verdict {
    if flush_wbuf(c).is_err() {
        return Verdict::Dead;
    }
    if c.pending_out() > cfg.write_hard_cap {
        // the peer asked for far more than it is willing to read; its
        // responses cannot be buffered without bound
        eprintln!("cscam-net: dropping connection: write buffer over hard cap");
        return Verdict::Dead;
    }
    if matches!(c.phase, Phase::Goodbye { .. }) && c.pending_out() == 0 {
        return Verdict::Dead; // goodbye delivered
    }
    let want = Interest { read: c.wants_read(cfg), write: c.pending_out() > 0 };
    if want != c.interest
        && poller.modify(c.stream.as_raw_fd(), c.token, want).is_ok()
    {
        c.interest = want;
    }
    Verdict::Alive
}

fn server_hello(fleet: &ShardedServerHandle, busy: bool) -> ServerHello {
    ServerHello {
        version: VERSION,
        busy,
        multiplex: true,
        shards: fleet.shard_count() as u32,
        bank_m: fleet.bank_m() as u32,
        tag_bits: fleet.tag_bits() as u32,
    }
}

// ------------------------------------------------------- request handling

/// Reject tags of the wrong width before they reach the router: the
/// engines answer a mismatch with a typed `TagWidth` error, but the
/// learned-prefix router reads fixed bit positions and would panic on a
/// too-narrow tag — a client mistake must never take down a worker.
fn check_width(fleet: &ShardedServerHandle, tag: &crate::bits::BitVec) -> Option<EngineError> {
    let want = fleet.tag_bits();
    (tag.len() != want).then(|| EngineError::TagWidth { got: tag.len(), want })
}

fn handle_request(
    fleet: &ShardedServerHandle,
    req: Request,
    scratch: &mut DecodeScratch,
    repl: Option<&ReplRole>,
) -> Response {
    match req {
        Request::Insert { tag } => {
            if let Some(e) = check_width(fleet, &tag) {
                return proto::error_response(&e);
            }
            // replica role: the mutation goes to the primary and comes
            // back through the log — never applied locally out of band
            if let Some(ReplRole::Replica(fw)) = repl {
                return match fw.insert(&tag) {
                    Ok(addr) => Response::Inserted { addr },
                    Err(e) => forward_error_response("insert", e),
                };
            }
            match fleet.insert(tag) {
                Ok(a) => Response::Inserted { addr: a as u64 },
                Err(e) => proto::error_response(&e),
            }
        }
        Request::Delete { addr } => {
            if let Some(ReplRole::Replica(fw)) = repl {
                return match fw.delete(addr) {
                    Ok(()) => Response::Deleted,
                    Err(e) => forward_error_response("delete", e),
                };
            }
            match fleet.delete(addr as usize) {
                Ok(()) => Response::Deleted,
                Err(e) => proto::error_response(&e),
            }
        }
        Request::Lookup { tag } => {
            // direct read: this worker snapshots the owning bank's state
            // and searches in place — no queue admission, identical bits
            // to the in-process path
            match fleet.lookup_direct(&tag, scratch) {
                Ok(o) => Response::Lookup(Box::new(o)),
                Err(e) => proto::error_response(&e),
            }
        }
        Request::LookupBulk { tags } => {
            // reject the whole frame on any bad width (a half-answered
            // frame would desync the client's per-item accounting)
            if let Some(e) = tags.iter().find_map(|t| check_width(fleet, t)) {
                return proto::error_response(&e);
            }
            Response::LookupBulk(fleet.lookup_many_direct(&tags, scratch))
        }
        Request::Stats => match stats_report(fleet) {
            Some(s) => Response::Stats(Box::new(s)),
            None => proto::error_response(&EngineError::Shutdown),
        },
        Request::Drain => {
            fleet.drain();
            Response::Drained
        }
        Request::Shutdown => {
            // the canonical drain-then-flush so the ack means "all accepted
            // work is done and durable"; the worker flips the stop flag
            // after a successful ack.  A failed flush must NOT ack — the
            // client would believe acked writes are on disk when they are
            // not — so it answers ERR_PERSIST and the server keeps serving
            // (the operator can retry or investigate).
            match fleet.shutdown() {
                Ok(_) => Response::ShutdownAck,
                Err(e) => persist_error_response("shutdown flush", e),
            }
        }
        Request::Snapshot => match fleet.snapshot_stores() {
            Ok(_) => Response::Snapshotted,
            Err(e) => persist_error_response("snapshot", e),
        },
        Request::Flush => match fleet.flush_stores() {
            Ok(_) => Response::Flushed,
            Err(e) => persist_error_response("flush", e),
        },
        Request::Metrics => match fleet.fleet_metrics() {
            // the wire op has no recovery report (that context lives with
            // the process that opened the data dir — the HTTP sidecar
            // renders it); everything else matches `GET /metrics`
            Some(fm) => {
                let repl_status = match repl {
                    Some(ReplRole::Primary(feed)) => Some(feed.status()),
                    _ => None,
                };
                Response::Metrics {
                    text: crate::obs::render_prometheus(
                        &fm,
                        fleet.bank_m(),
                        fleet.tag_bits(),
                        None,
                        repl_status.as_ref(),
                    ),
                }
            }
            None => proto::error_response(&EngineError::Shutdown),
        },
        Request::SubscribeLog { replica, epoch, bank, generation, offset } => match repl {
            Some(ReplRole::Primary(feed)) => feed.serve(replica, epoch, bank, generation, offset),
            // no feed here (in-memory fleet, or a replica — chaining is
            // not supported): the op is unknown to this server
            _ => Response::Error {
                code: proto::ERR_UNKNOWN_OP,
                aux: u64::from(proto::OP_SUBSCRIBE_LOG),
            },
        },
    }
}

/// Map a failed forwarded mutation onto the wire: typed engine errors
/// pass through untouched (the primary's verdict), admission shedding
/// stays `ERR_BUSY`, and a transport failure — the primary unreachable,
/// so the write was *not* accepted anywhere — answers `ERR_PERSIST` with
/// the detail in the server log.
fn forward_error_response(what: &str, e: WireError) -> Response {
    match e {
        WireError::Engine(e) => proto::error_response(&e),
        WireError::Busy => Response::Error { code: proto::ERR_BUSY, aux: 0 },
        other => {
            eprintln!("cscam-net: forwarded {what} failed: {other}");
            Response::Error { code: proto::ERR_PERSIST, aux: 0 }
        }
    }
}

/// Map a persistence failure onto the wire: a dead engine thread is the
/// usual `Shutdown`, a store failure is `ERR_PERSIST` (details stay in the
/// server log — the operator owns the disk, not the client).
fn persist_error_response(what: &str, e: PersistError) -> Response {
    match e {
        PersistError::Shutdown => proto::error_response(&EngineError::Shutdown),
        PersistError::Store(e) => {
            eprintln!("cscam-net: {what} failed: {e}");
            Response::Error { code: proto::ERR_PERSIST, aux: 0 }
        }
    }
}

fn stats_report(fleet: &ShardedServerHandle) -> Option<StatsReport> {
    let fm = fleet.fleet_metrics()?;
    Some(StatsReport {
        shards: fleet.shard_count() as u32,
        bank_m: fleet.bank_m() as u32,
        tag_bits: fleet.tag_bits() as u32,
        lookups: fm.aggregate.lookups,
        hits: fm.aggregate.hits,
        misses: fm.aggregate.misses,
        inserts: fm.aggregate.inserts,
        deletes: fm.aggregate.deletes,
        mean_lambda: fm.aggregate.lambda.mean(),
        mean_energy_fj: fm.aggregate.energy_fj.mean(),
        p50_ns: fm.aggregate.host_latency_ns.quantile(0.5),
        p99_ns: fm.aggregate.host_latency_ns.quantile(0.99),
        hottest_bank: fm.hottest_bank() as u32,
        hot_fraction: fm.hot_fraction(),
        per_bank_lookups: fm.per_bank.iter().map(|m| m.lookups).collect(),
    })
}
