//! Statistics: online accumulators, histograms, and the ambiguity (λ)
//! estimators behind Fig. 3.

pub mod ambiguity;

pub use ambiguity::{expected_comparisons, expected_lambda, simulate_lambda, LambdaEstimate};


/// Numerically stable online mean/variance (Welford).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// The mean, or `default` when no samples have been pushed — for
    /// summaries and serialized rows where NaN would poison the output.
    pub fn mean_or(&self, default: f64) -> f64 {
        if self.n == 0 {
            default
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency/size histogram with u64 samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Buckets: (−∞, bounds[0]], (bounds[0], bounds[1]], …, (last, ∞).
    pub fn new(bounds: Vec<u64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must be ascending");
        let n = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; n], total: 0 }
    }

    /// Exponential buckets 1, 2, 4, … up to `max`.
    pub fn exponential(max: u64) -> Self {
        let mut bounds = Vec::new();
        let mut b = 1u64;
        while b <= max {
            bounds.push(b);
            b *= 2;
        }
        Self::new(bounds)
    }

    /// HDR-style log-linear buckets up to `max`: each power-of-2 major
    /// span is cut into [`Self::LOG_LINEAR_SUB`] equal sub-buckets, so
    /// the relative quantile error is bounded by one sub-bucket
    /// (width/lo ≤ 1/16 ≈ 6.25 %) instead of the up-to-2x of
    /// [`Self::exponential`].  Values ≤ 2·16 get exact unit buckets.
    /// Same `record`/`quantile`/`merge` contract.
    pub fn log_linear(max: u64) -> Self {
        const SUB: u64 = Histogram::LOG_LINEAR_SUB;
        let mut bounds: Vec<u64> = (1..=(2 * SUB).min(max)).collect();
        let mut major = 2 * SUB;
        while major < max {
            let width = major / SUB;
            for i in 1..=SUB {
                let b = major + i * width;
                bounds.push(b);
                if b >= max {
                    break;
                }
            }
            major *= 2;
        }
        Self::new(bounds)
    }

    /// Linear sub-buckets per power-of-2 major span in [`Self::log_linear`].
    pub const LOG_LINEAR_SUB: u64 = 16;

    pub fn record(&mut self, v: u64) {
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Upper bound of the bucket containing quantile `q` ∈ [0,1]
    /// (`u64::MAX` for the overflow bucket).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut cum = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return self.bounds.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Bucket-wise merge of a histogram with identical bounds (shard
    /// aggregation). Panics on layout mismatch.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram layouts differ");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(u64::MAX))
            .zip(self.counts.iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = OnlineStats::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        xs[..37].iter().for_each(|&x| a.push(x));
        xs[37..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::exponential(1 << 10);
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.total(), 1000);
        assert!(h.quantile(0.5) >= 512 / 2 && h.quantile(0.5) <= 512);
        assert!(h.quantile(1.0) >= 1000);
        assert!(h.quantile(0.0) <= 2);
    }

    #[test]
    fn histogram_merge_adds_buckets() {
        let mut a = Histogram::new(vec![10, 20]);
        let mut b = Histogram::new(vec![10, 20]);
        a.record(5);
        b.record(15);
        b.record(25);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        let counts: Vec<_> = a.buckets().map(|(_, c)| c).collect();
        assert_eq!(counts, vec![1, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "histogram layouts differ")]
    fn histogram_merge_rejects_mismatched_layouts() {
        let mut a = Histogram::new(vec![10]);
        a.merge(&Histogram::new(vec![20]));
    }

    #[test]
    fn log_linear_layout_units_then_sixteenths() {
        let h = Histogram::log_linear(1 << 10);
        let bounds: Vec<u64> = h.buckets().map(|(b, _)| b).collect();
        // exact unit buckets through two majors…
        assert_eq!(&bounds[..32], (1..=32).collect::<Vec<u64>>().as_slice());
        // …then 16 width-2 sub-buckets spanning (32, 64]
        let expect: Vec<u64> = (1..=16).map(|i| 32 + 2 * i).collect();
        assert_eq!(&bounds[32..48], expect.as_slice());
        // strictly ascending end to end (Histogram::new asserts, but make
        // the layout contract explicit here)
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn log_linear_quantile_error_within_one_sub_bucket_of_oracle() {
        // property test: across seeded distributions, the log-linear
        // quantile never undershoots the sorted-vector oracle and
        // overshoots by at most one sub-bucket (relative error ≤ 1/16)
        use crate::util::Rng;
        let max = 1u64 << 24;
        for seed in [11u64, 23, 47, 91, 150] {
            let mut rng = Rng::seed_from_u64(seed);
            let mut h = Histogram::log_linear(max);
            let mut vals: Vec<u64> = Vec::new();
            for i in 0..5000 {
                let v = match i % 3 {
                    0 => 1 + rng.gen_u64() % 1000, // low values, unit buckets
                    1 => 1 + rng.gen_u64() % max,  // uniform across the range
                    _ => (1u64 << (rng.gen_u64() % 24)) + rng.gen_u64() % 17, // log spread
                };
                let v = v.min(max);
                h.record(v);
                vals.push(v);
            }
            vals.sort_unstable();
            for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                let target = ((q * vals.len() as f64).ceil() as usize).max(1);
                let oracle = vals[target - 1];
                let got = h.quantile(q);
                assert!(got >= oracle, "q={q} seed={seed}: {got} undershoots oracle {oracle}");
                assert!(
                    (got - oracle) as f64 <= oracle as f64 / 16.0,
                    "q={q} seed={seed}: {got} vs oracle {oracle} exceeds one sub-bucket"
                );
            }
        }
    }

    #[test]
    fn log_linear_merge_matches_union() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(7);
        let mut whole = Histogram::log_linear(1 << 20);
        let mut a = Histogram::log_linear(1 << 20);
        let mut b = Histogram::log_linear(1 << 20);
        for i in 0..2000 {
            let v = 1 + rng.gen_u64() % (1 << 20);
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::new(vec![10, 20]);
        h.record(5);
        h.record(15);
        h.record(99);
        let counts: Vec<_> = h.buckets().map(|(_, c)| c).collect();
        assert_eq!(counts, vec![1, 1, 1]);
        assert_eq!(h.quantile(1.0), u64::MAX);
    }
}
